//! Generic prime field `Fp<P, N>` over `N` 64-bit limbs.
//!
//! Elements are stored in **Montgomery form** (radix `R = 2^(64·N)`); the
//! multiplier is a fused CIOS (coarsely integrated operand scanning)
//! Montgomery multiply — the software analogue of the paper's pipelined
//! Montgomery multiplier (§IV-B1). The paper's final design abandons
//! Montgomery for a LUT-based "standard form" reduction; that path is
//! implemented in [`super::barrett`] and verified to agree with this one.
//!
//! Every modular multiplication/squaring is counted through
//! [`super::opcount`], which is how Tables II and III of the paper are
//! regenerated from *measured* operation counts rather than formulas.

use super::bigint::{self, adc, mac, sbb};
use super::lanes::FpLanes;
use super::opcount;
use crate::util::rng::Rng;
use std::fmt;
use std::hash::Hash;
use std::marker::PhantomData;

/// Static description of a prime field: the modulus plus the generator data
/// the NTT and square-root machinery need.
pub trait FieldParams<const N: usize>:
    'static + Copy + Clone + Send + Sync + fmt::Debug + PartialEq + Eq + Hash
{
    /// Little-endian limbs of the (odd, prime) modulus.
    const MODULUS: [u64; N];
    /// Bit length of the modulus.
    const BITS: u32;
    /// Small multiplicative generator of the field (primitive root).
    const GENERATOR: u64;
    /// Largest s with 2^s | (p-1) — drives NTT domain sizes.
    const TWO_ADICITY: u32;
    /// Display name.
    const NAME: &'static str;
}

/// Behaviour shared by all fields in the crate (prime and extension); the
/// generic consumers — EC groups, NTT, Tonelli–Shanks, QAP — are written
/// against this.
pub trait Field:
    Copy + Clone + fmt::Debug + PartialEq + Eq + Send + Sync + 'static + Hash
{
    /// The additive identity.
    fn zero() -> Self;
    /// The multiplicative identity.
    fn one() -> Self;
    /// Is this the additive identity?
    fn is_zero(&self) -> bool;
    /// Field addition.
    fn add(&self, other: &Self) -> Self;
    /// Field subtraction.
    fn sub(&self, other: &Self) -> Self;
    /// Additive inverse.
    fn neg(&self) -> Self;
    /// Field multiplication.
    fn mul(&self, other: &Self) -> Self;
    /// Squaring (specialized where cheaper than `mul(self)`).
    fn square(&self) -> Self;
    /// 2·self.
    fn double(&self) -> Self {
        self.add(self)
    }
    /// Multiplicative inverse (None for zero).
    fn inv(&self) -> Option<Self>;
    /// Embed a small integer.
    fn from_u64(v: u64) -> Self;
    /// Uniform random element.
    fn random(rng: &mut Rng) -> Self;
    /// Exponentiation by a little-endian limb slice.
    fn pow_limbs(&self, exp: &[u64]) -> Self {
        let mut out = Self::one();
        let mut found_one = false;
        for i in (0..exp.len() * 64).rev() {
            if found_one {
                out = out.square();
            }
            if (exp[i / 64] >> (i % 64)) & 1 == 1 {
                out = out.mul(self);
                found_one = true;
            }
        }
        out
    }
    /// Exponentiation by a 64-bit exponent.
    fn pow_u64(&self, e: u64) -> Self {
        self.pow_limbs(&[e])
    }
    /// Order of the field minus one, as little-endian limbs (q−1; for Fp
    /// this is p−1, for Fp² it is p²−1). Drives generic Tonelli–Shanks.
    fn order_minus_one() -> Vec<u64>;

    /// Four independent multiplications: `out[l] = a[l]·b[l]`, with no
    /// cross-lane data flow. The default is the scalar loop (what
    /// extension fields keep); [`Fp`] overrides it with the
    /// limb-interleaved 4-lane Montgomery core in [`super::lanes`].
    /// Counts 4 muls either way, so pinned op budgets stay honest, and
    /// each lane is bit-identical to the scalar op by construction.
    #[inline]
    fn mul4(a: &[Self; 4], b: &[Self; 4]) -> [Self; 4] {
        [a[0].mul(&b[0]), a[1].mul(&b[1]), a[2].mul(&b[2]), a[3].mul(&b[3])]
    }
    /// Four independent squarings (see [`Field::mul4`]).
    #[inline]
    fn square4(a: &[Self; 4]) -> [Self; 4] {
        [a[0].square(), a[1].square(), a[2].square(), a[3].square()]
    }
    /// Four independent additions (see [`Field::mul4`]).
    #[inline]
    fn add4(a: &[Self; 4], b: &[Self; 4]) -> [Self; 4] {
        [a[0].add(&b[0]), a[1].add(&b[1]), a[2].add(&b[2]), a[3].add(&b[3])]
    }
    /// Four independent subtractions (see [`Field::mul4`]).
    #[inline]
    fn sub4(a: &[Self; 4], b: &[Self; 4]) -> [Self; 4] {
        [a[0].sub(&b[0]), a[1].sub(&b[1]), a[2].sub(&b[2]), a[3].sub(&b[3])]
    }
    /// Four independent doublings (see [`Field::mul4`]).
    #[inline]
    fn double4(a: &[Self; 4]) -> [Self; 4] {
        [a[0].double(), a[1].double(), a[2].double(), a[3].double()]
    }
}

/// A prime-field element in Montgomery form.
#[derive(Clone, Copy)]
pub struct Fp<P: FieldParams<N>, const N: usize> {
    /// Montgomery representation: (value · R) mod p.
    pub(crate) mont: [u64; N],
    _p: PhantomData<P>,
}

impl<P: FieldParams<N>, const N: usize> Fp<P, N> {
    /// −p⁻¹ mod 2⁶⁴ (CIOS constant), derived at compile time.
    pub const INV: u64 = bigint::mont_inv64(P::MODULUS[0]);
    /// R mod p — the Montgomery image of 1.
    pub const R: [u64; N] = bigint::compute_r::<N>(&P::MODULUS);
    /// R² mod p — converts canonical → Montgomery via one mont-mul.
    pub const R2: [u64; N] = bigint::compute_r2::<N>(&P::MODULUS);
    /// Word (u64 × u64) multiplications one fused CIOS [`Field::mul`]
    /// issues: per outer pass, N operand muls + 1 `m` derivation + N
    /// reduction muls ⇒ N·(2N + 1) — 36 at N = 4, 78 at N = 6. The
    /// baseline the dedicated squaring is pinned against.
    pub const MUL_WORD_MULS: u64 = (N as u64) * (2 * N as u64 + 1);
    /// Word multiplications one SOS [`Field::square`] issues:
    /// N(N−1)/2 upper-triangle cross terms (the doubling is a shift, not
    /// a multiply) + N diagonal squares + N(N+1) reduction muls (incl.
    /// the per-pass `m`) ⇒ (3N² + 3N)/2 — 30 at N = 4, 63 at N = 6,
    /// a ≈17–19% word-mul saving over [`Self::MUL_WORD_MULS`].
    pub const SQUARE_WORD_MULS: u64 = (3 * (N as u64) * (N as u64) + 3 * N as u64) / 2;

    /// Construct from raw Montgomery limbs (internal, must be < p).
    #[inline]
    pub(crate) const fn from_mont(mont: [u64; N]) -> Self {
        Fp { mont, _p: PhantomData }
    }

    /// Construct from canonical little-endian limbs; returns `None` if the
    /// value is ≥ p.
    pub fn from_canonical(limbs: [u64; N]) -> Option<Self> {
        if bigint::gte(&limbs, &P::MODULUS) {
            return None;
        }
        Some(Fp::from_mont(Self::mont_mul(&limbs, &Self::R2)))
    }

    /// Construct reducing an arbitrary limb value mod p (slow path: repeated
    /// conditional subtraction only valid for < 2p; general values use
    /// shift-add reduction).
    pub fn from_limbs_reduce(limbs: [u64; N]) -> Self {
        let mut v = limbs;
        while bigint::gte(&v, &P::MODULUS) {
            let (d, _) = bigint::sub(&v, &P::MODULUS);
            v = d;
        }
        Fp::from_mont(Self::mont_mul(&v, &Self::R2))
    }

    /// Canonical little-endian limbs (undoes the Montgomery encoding).
    pub fn to_canonical(&self) -> [u64; N] {
        let mut one = [0u64; N];
        one[0] = 1;
        Self::mont_mul_uncounted(&self.mont, &one)
    }

    /// Canonical hex string.
    pub fn to_hex(&self) -> String {
        crate::util::hex::limbs_to_hex(&self.to_canonical())
    }

    /// Parse a canonical hex string.
    pub fn from_hex(s: &str) -> Result<Self, String> {
        let v = crate::util::hex::hex_to_limbs(s, N)?;
        let mut limbs = [0u64; N];
        limbs.copy_from_slice(&v);
        Self::from_canonical(limbs).ok_or_else(|| format!("value >= modulus of {}", P::NAME))
    }

    /// The raw Montgomery limbs (for the 16-bit repacking used by the PJRT
    /// engine — Montgomery form is radix-independent for equal R).
    pub fn mont_limbs(&self) -> &[u64; N] {
        &self.mont
    }

    /// Rebuild from Montgomery limbs produced by the engine (must be < p).
    pub fn from_mont_limbs(limbs: [u64; N]) -> Option<Self> {
        if bigint::gte(&limbs, &P::MODULUS) {
            return None;
        }
        Some(Fp::from_mont(limbs))
    }

    /// Fused CIOS Montgomery multiplication: returns a·b·R⁻¹ mod p.
    #[inline]
    fn mont_mul_uncounted(a: &[u64; N], b: &[u64; N]) -> [u64; N] {
        // Koç/Acar CIOS with the two extra accumulator words held in
        // registers. All intermediates fit because p < 2^(64N−1) for both
        // supported fields (254/381 bits in 256/384).
        let mut t = [0u64; N];
        let mut t_n = 0u64; // t[N]
        let mut t_n1 = 0u64; // t[N+1], 0 or 1
        for i in 0..N {
            // t += a[i] * b
            let mut carry = 0u64;
            for j in 0..N {
                let (lo, hi) = mac(t[j], a[i], b[j], carry);
                t[j] = lo;
                carry = hi;
            }
            let (s, c) = adc(t_n, carry, 0);
            t_n = s;
            t_n1 = c;

            // m = t[0] · (−p⁻¹) mod 2⁶⁴ ; t += m·p ; t >>= 64
            let m = t[0].wrapping_mul(Self::INV);
            let (_, mut carry) = mac(t[0], m, P::MODULUS[0], 0);
            for j in 1..N {
                let (lo, hi) = mac(t[j], m, P::MODULUS[j], carry);
                t[j - 1] = lo;
                carry = hi;
            }
            let (s, c) = adc(t_n, carry, 0);
            t[N - 1] = s;
            t_n = t_n1 + c; // t_n1 is rewritten at the top of the next pass
        }
        // Final conditional subtraction.
        if t_n > 0 || bigint::gte(&t, &P::MODULUS) {
            let (d, _) = bigint::sub(&t, &P::MODULUS);
            t = d;
        }
        t
    }

    #[inline]
    fn mont_mul(a: &[u64; N], b: &[u64; N]) -> [u64; N] {
        opcount::count_mul();
        Self::mont_mul_uncounted(a, b)
    }

    /// Dedicated SOS (separated operand scanning) Montgomery squaring:
    /// returns a²·R⁻¹ mod p. The product phase computes only the upper
    /// triangle of cross terms and doubles the whole strip with a one-bit
    /// shift — the symmetric saving the fused CIOS multiply cannot
    /// exploit — then adds the diagonal squares and runs the standard
    /// word-by-word Montgomery reduction. Word-mul budget:
    /// [`Self::SQUARE_WORD_MULS`] vs [`Self::MUL_WORD_MULS`].
    #[inline]
    fn mont_sqr_uncounted(a: &[u64; N]) -> [u64; N] {
        // Fixed 16-limb scratch stands in for [u64; 2N] (stable Rust has
        // no const-generic arithmetic); both supported fields fit (N ≤ 6).
        debug_assert!(2 * N <= 16, "SOS scratch supports N <= 8");
        let mut r = [0u64; 16];

        // Upper-triangle cross products a[i]·a[j], i < j.
        for i in 0..N {
            let mut carry = 0u64;
            for j in (i + 1)..N {
                let (lo, hi) = mac(r[i + j], a[i], a[j], carry);
                r[i + j] = lo;
                carry = hi;
            }
            r[i + N] = carry;
        }

        // Double the cross strip: one-bit left shift across 2N limbs
        // (r[0] is untouched — no cross term lands below index 1).
        r[2 * N - 1] = r[2 * N - 2] >> 63;
        for i in (2..=(2 * N - 2)).rev() {
            r[i] = (r[i] << 1) | (r[i - 1] >> 63);
        }
        r[1] <<= 1;

        // Add the diagonal a[i]².
        let mut carry = 0u64;
        for i in 0..N {
            let (lo, hi) = mac(r[2 * i], a[i], a[i], carry);
            r[2 * i] = lo;
            let (s, c) = adc(r[2 * i + 1], hi, 0);
            r[2 * i + 1] = s;
            carry = c;
        }
        debug_assert_eq!(carry, 0, "a^2 fits 2N limbs");

        // Word-by-word Montgomery reduction of the 2N-limb square.
        let mut carry2 = 0u64;
        for i in 0..N {
            let m = r[i].wrapping_mul(Self::INV);
            let (_, mut carry) = mac(r[i], m, P::MODULUS[0], 0);
            for j in 1..N {
                let (lo, hi) = mac(r[i + j], m, P::MODULUS[j], carry);
                r[i + j] = lo;
                carry = hi;
            }
            let (s, c) = adc(r[i + N], carry2, carry);
            r[i + N] = s;
            carry2 = c;
        }
        // p < 2^(64N−1) ⇒ a² + Σ mᵢ·p·2^(64i) < 2^(128N): no carry out.
        debug_assert_eq!(carry2, 0);

        let mut out = [0u64; N];
        out.copy_from_slice(&r[N..2 * N]);
        // The reduced value is < 2p: one conditional subtraction suffices.
        if bigint::gte(&out, &P::MODULUS) {
            let (d, _) = bigint::sub(&out, &P::MODULUS);
            out = d;
        }
        out
    }
}

impl<P: FieldParams<N>, const N: usize> PartialEq for Fp<P, N> {
    fn eq(&self, other: &Self) -> bool {
        self.mont == other.mont
    }
}
impl<P: FieldParams<N>, const N: usize> Eq for Fp<P, N> {}

impl<P: FieldParams<N>, const N: usize> Hash for Fp<P, N> {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.mont.hash(state);
    }
}

impl<P: FieldParams<N>, const N: usize> fmt::Debug for Fp<P, N> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}({})", P::NAME, self.to_hex())
    }
}

impl<P: FieldParams<N>, const N: usize> Field for Fp<P, N> {
    #[inline]
    fn zero() -> Self {
        Fp::from_mont([0u64; N])
    }

    #[inline]
    fn one() -> Self {
        Fp::from_mont(Self::R)
    }

    #[inline]
    fn is_zero(&self) -> bool {
        bigint::is_zero(&self.mont)
    }

    #[inline]
    fn add(&self, other: &Self) -> Self {
        opcount::count_add();
        let (s, carry) = bigint::add(&self.mont, &other.mont);
        // Both operands < p < 2^(64N−1) ⇒ no carry-out possible, but keep
        // the check for safety in debug builds.
        debug_assert_eq!(carry, 0);
        if bigint::gte(&s, &P::MODULUS) {
            let (d, _) = bigint::sub(&s, &P::MODULUS);
            Fp::from_mont(d)
        } else {
            Fp::from_mont(s)
        }
    }

    #[inline]
    fn sub(&self, other: &Self) -> Self {
        opcount::count_add();
        let (d, borrow) = bigint::sub(&self.mont, &other.mont);
        if borrow == 1 {
            let (r, _) = bigint::add(&d, &P::MODULUS);
            Fp::from_mont(r)
        } else {
            Fp::from_mont(d)
        }
    }

    #[inline]
    fn neg(&self) -> Self {
        if self.is_zero() {
            *self
        } else {
            let (d, _) = bigint::sub(&P::MODULUS, &self.mont);
            Fp::from_mont(d)
        }
    }

    #[inline]
    fn mul(&self, other: &Self) -> Self {
        Fp::from_mont(Self::mont_mul(&self.mont, &other.mont))
    }

    #[inline]
    fn square(&self) -> Self {
        opcount::count_square();
        Fp::from_mont(Self::mont_sqr_uncounted(&self.mont))
    }

    #[inline]
    fn double(&self) -> Self {
        opcount::count_add();
        let (d, carry) = bigint::double(&self.mont);
        debug_assert_eq!(carry, 0);
        if bigint::gte(&d, &P::MODULUS) {
            let (r, _) = bigint::sub(&d, &P::MODULUS);
            Fp::from_mont(r)
        } else {
            Fp::from_mont(d)
        }
    }

    fn inv(&self) -> Option<Self> {
        if self.is_zero() {
            return None;
        }
        opcount::count_inv();
        // Fermat: a^(p−2). Exponent p−2 computed on the fly.
        let mut exp = P::MODULUS;
        // subtract 2 (p is odd and > 2, so no borrow past limb 1)
        let (d0, borrow) = sbb(exp[0], 2, 0);
        exp[0] = d0;
        if borrow == 1 {
            let mut i = 1;
            loop {
                let (di, bo) = sbb(exp[i], 0, 1);
                exp[i] = di;
                if bo == 0 {
                    break;
                }
                i += 1;
            }
        }
        Some(self.pow_limbs(&exp))
    }

    fn from_u64(v: u64) -> Self {
        let mut limbs = [0u64; N];
        limbs[0] = v;
        // v may exceed p only for pathological tiny moduli — not our fields.
        Fp::from_mont(Self::mont_mul(&limbs, &Self::R2))
    }

    fn random(rng: &mut Rng) -> Self {
        // Rejection-sample below p for uniformity.
        let top_bits = P::BITS - 64 * (N as u32 - 1);
        let mask = if top_bits >= 64 { u64::MAX } else { (1u64 << top_bits) - 1 };
        loop {
            let mut limbs = [0u64; N];
            for l in limbs.iter_mut() {
                *l = rng.next_u64();
            }
            limbs[N - 1] &= mask;
            if bigint::lt(&limbs, &P::MODULUS) {
                return Fp::from_mont(Self::mont_mul(&limbs, &Self::R2));
            }
        }
    }

    fn order_minus_one() -> Vec<u64> {
        let mut v = P::MODULUS.to_vec();
        v[0] -= 1; // p odd ⇒ no borrow
        v
    }

    // Lane overrides: route through the limb-interleaved 4-lane core so
    // generic consumers (batch-affine fill, batch_invert) vectorize
    // automatically over prime fields while extension fields keep the
    // scalar defaults.
    #[inline]
    fn mul4(a: &[Self; 4], b: &[Self; 4]) -> [Self; 4] {
        FpLanes::from_elems(a).mul4(&FpLanes::from_elems(b)).to_elems()
    }
    #[inline]
    fn square4(a: &[Self; 4]) -> [Self; 4] {
        FpLanes::from_elems(a).square4().to_elems()
    }
    #[inline]
    fn add4(a: &[Self; 4], b: &[Self; 4]) -> [Self; 4] {
        FpLanes::from_elems(a).add4(&FpLanes::from_elems(b)).to_elems()
    }
    #[inline]
    fn sub4(a: &[Self; 4], b: &[Self; 4]) -> [Self; 4] {
        FpLanes::from_elems(a).sub4(&FpLanes::from_elems(b)).to_elems()
    }
    #[inline]
    fn double4(a: &[Self; 4]) -> [Self; 4] {
        FpLanes::from_elems(a).double4().to_elems()
    }
}

// Operator sugar.
impl<P: FieldParams<N>, const N: usize> std::ops::Add for Fp<P, N> {
    type Output = Self;
    fn add(self, rhs: Self) -> Self {
        Field::add(&self, &rhs)
    }
}
impl<P: FieldParams<N>, const N: usize> std::ops::Sub for Fp<P, N> {
    type Output = Self;
    fn sub(self, rhs: Self) -> Self {
        Field::sub(&self, &rhs)
    }
}
impl<P: FieldParams<N>, const N: usize> std::ops::Mul for Fp<P, N> {
    type Output = Self;
    fn mul(self, rhs: Self) -> Self {
        Field::mul(&self, &rhs)
    }
}
impl<P: FieldParams<N>, const N: usize> std::ops::Neg for Fp<P, N> {
    type Output = Self;
    fn neg(self) -> Self {
        Field::neg(&self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::params::{Bls12381FpParams, Bn254FpParams, Bn254FrParams};

    type FpBn = Fp<Bn254FpParams, 4>;
    type FrBn = Fp<Bn254FrParams, 4>;
    type FpBls = Fp<Bls12381FpParams, 6>;

    #[test]
    fn one_times_one() {
        assert_eq!(FpBn::one().mul(&FpBn::one()), FpBn::one());
        assert_eq!(FpBls::one().mul(&FpBls::one()), FpBls::one());
    }

    #[test]
    fn add_sub_roundtrip() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let a = FpBn::random(&mut rng);
            let b = FpBn::random(&mut rng);
            assert_eq!(a.add(&b).sub(&b), a);
            assert_eq!(a.sub(&b).add(&b), a);
        }
    }

    #[test]
    fn mul_matches_known_small_values() {
        // 3 * 5 = 15 in any field with p > 15
        let a = FpBn::from_u64(3);
        let b = FpBn::from_u64(5);
        assert_eq!(a.mul(&b), FpBn::from_u64(15));
        let a = FpBls::from_u64(1u64 << 40);
        let b = FpBls::from_u64(1u64 << 23);
        assert_eq!(a.mul(&b), FpBls::from_u64(1u64 << 63));
    }

    #[test]
    fn square_matches_mul() {
        // the dedicated SOS squaring must agree with the fused CIOS
        // multiply everywhere — random elements, both limb widths
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let a = FpBls::random(&mut rng);
            assert_eq!(a.square(), a.mul(&a));
            let b = FpBn::random(&mut rng);
            assert_eq!(b.square(), b.mul(&b));
            let c = FrBn::random(&mut rng);
            assert_eq!(c.square(), c.mul(&c));
        }
    }

    #[test]
    fn square_matches_mul_on_edge_values() {
        // boundary operands stress the shift-doubling and the final
        // conditional subtraction: 0, 1, 2, p−1, p−2, all-ones-limb words
        fn check<P: FieldParams<N>, const N: usize>() {
            let mut edges = vec![
                Fp::<P, N>::zero(),
                Fp::<P, N>::one(),
                Fp::<P, N>::from_u64(2),
                Fp::<P, N>::from_u64(u64::MAX),
                Fp::<P, N>::one().neg(),        // p − 1
                Fp::<P, N>::from_u64(2).neg(),  // p − 2
            ];
            // a value with every limb's top bit set (max carry pressure)
            edges.push(Fp::<P, N>::from_limbs_reduce([0x8000_0000_0000_0000u64; N]));
            for a in edges {
                assert_eq!(a.square(), a.mul(&a), "{}: {:?}", P::NAME, a);
            }
        }
        check::<Bn254FpParams, 4>();
        check::<Bn254FrParams, 4>();
        check::<Bls12381FpParams, 6>();
    }

    #[test]
    fn sos_word_mul_pins() {
        // the symmetric-cross-term saving, pinned exactly: the squaring
        // must stay cheaper than the multiply in word muls
        assert_eq!(FpBn::MUL_WORD_MULS, 36);
        assert_eq!(FpBn::SQUARE_WORD_MULS, 30);
        assert_eq!(FpBls::MUL_WORD_MULS, 78);
        assert_eq!(FpBls::SQUARE_WORD_MULS, 63);
        assert!(FpBn::SQUARE_WORD_MULS < FpBn::MUL_WORD_MULS);
        assert!(FpBls::SQUARE_WORD_MULS < FpBls::MUL_WORD_MULS);
    }

    #[test]
    fn square_counts_as_square_not_mul() {
        // the dedicated path must keep the opcount split intact (the
        // Tables II/III modmul source is mul + square)
        let mut rng = Rng::new(9);
        let a = FpBn::random(&mut rng);
        let (_, ops) = crate::ff::opcount::measure(|| {
            let mut x = a;
            for _ in 0..7 {
                x = x.square();
            }
            x
        });
        assert_eq!(ops.square, 7);
        assert_eq!(ops.mul, 0);
        assert_eq!(ops.modmuls(), 7);
    }

    #[test]
    fn inverse_is_inverse() {
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            let a = FpBn::random(&mut rng);
            if a.is_zero() {
                continue;
            }
            assert_eq!(a.mul(&a.inv().unwrap()), FpBn::one());
        }
        let a = FpBls::random(&mut rng);
        assert_eq!(a.mul(&a.inv().unwrap()), FpBls::one());
        assert!(FpBn::zero().inv().is_none());
    }

    #[test]
    fn neg_adds_to_zero() {
        let mut rng = Rng::new(4);
        let a = FpBls::random(&mut rng);
        assert!(a.add(&a.neg()).is_zero());
        assert_eq!(FpBn::zero().neg(), FpBn::zero());
    }

    #[test]
    fn fermat_little_theorem() {
        // a^(p-1) = 1 — exercises pow_limbs over the full modulus width.
        let mut rng = Rng::new(5);
        let a = FpBn::random(&mut rng);
        let exp = {
            let mut e = Bn254FpParams::MODULUS;
            e[0] -= 1;
            e
        };
        assert_eq!(a.pow_limbs(&exp), FpBn::one());
    }

    #[test]
    fn canonical_roundtrip() {
        let mut rng = Rng::new(6);
        for _ in 0..20 {
            let a = FpBls::random(&mut rng);
            let c = a.to_canonical();
            assert_eq!(FpBls::from_canonical(c).unwrap(), a);
        }
    }

    #[test]
    fn hex_roundtrip() {
        let a = FrBn::from_u64(0xdeadbeef);
        assert_eq!(FrBn::from_hex(&a.to_hex()).unwrap(), a);
        assert_eq!(a.to_hex(), "0xdeadbeef");
    }

    #[test]
    fn from_canonical_rejects_modulus() {
        assert!(FpBn::from_canonical(Bn254FpParams::MODULUS).is_none());
    }

    #[test]
    fn modulus_minus_one_squared() {
        // (p-1)^2 = p^2 - 2p + 1 ≡ 1 (mod p)
        let mut limbs = Bn254FpParams::MODULUS;
        limbs[0] -= 1;
        let a = FpBn::from_canonical(limbs).unwrap();
        assert_eq!(a.square(), FpBn::one());
    }

    #[test]
    fn distributive_law() {
        let mut rng = Rng::new(7);
        for _ in 0..20 {
            let (a, b, c) = (
                FpBls::random(&mut rng),
                FpBls::random(&mut rng),
                FpBls::random(&mut rng),
            );
            assert_eq!(a.mul(&b.add(&c)), a.mul(&b).add(&a.mul(&c)));
        }
    }

    #[test]
    fn generator_is_nonresidue_seed() {
        // generator^((p-1)/2) == -1 for the configured Fp generators —
        // validates the GENERATOR constants used by Tonelli–Shanks.
        fn check<P: FieldParams<N>, const N: usize>() {
            let g = Fp::<P, N>::from_u64(P::GENERATOR);
            let e = bigint::shr_slices(&Fp::<P, N>::order_minus_one(), 1);
            let lg = g.pow_limbs(&e);
            assert_eq!(lg, Fp::<P, N>::one().neg(), "{}", P::NAME);
        }
        check::<Bn254FpParams, 4>();
        check::<Bls12381FpParams, 6>();
    }

    #[test]
    fn double_matches_add() {
        let mut rng = Rng::new(8);
        let a = FpBn::random(&mut rng);
        assert_eq!(Field::double(&a), a.add(&a));
    }
}
