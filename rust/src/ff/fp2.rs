//! Quadratic extension field Fp² = Fp[u]/(u² + 1).
//!
//! Both BN254 and BLS12-381 have p ≡ 3 (mod 4), so −1 is a quadratic
//! nonresidue and u² = −1 is a valid (and the conventional) tower for the
//! G2 groups the prover's second MSM runs over (Table I's MSM-𝔾₂ column).
//! Multiplication is Karatsuba (3 base multiplications — the 3× cost factor
//! the paper's G2 future-work discussion refers to).

use super::fp::{Field, FieldParams, Fp};
use crate::util::rng::Rng;
use std::hash::Hash;

/// Element c0 + c1·u of Fp².
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Fp2<P: FieldParams<N>, const N: usize> {
    /// The base-field component.
    pub c0: Fp<P, N>,
    /// The u-component.
    pub c1: Fp<P, N>,
}

impl<P: FieldParams<N>, const N: usize> Fp2<P, N> {
    /// Build c0 + c1·u from components.
    pub const fn new(c0: Fp<P, N>, c1: Fp<P, N>) -> Self {
        Fp2 { c0, c1 }
    }

    /// Embed a base-field element.
    pub fn from_base(c0: Fp<P, N>) -> Self {
        Fp2 { c0, c1: Fp::<P, N>::zero() }
    }

    /// Conjugate c0 − c1·u.
    pub fn conjugate(&self) -> Self {
        Fp2 { c0: self.c0, c1: self.c1.neg() }
    }

    /// Norm map N(a) = a·ā = c0² + c1² ∈ Fp.
    pub fn norm(&self) -> Fp<P, N> {
        self.c0.square().add(&self.c1.square())
    }

    /// Multiply by a base-field scalar (2 base muls).
    pub fn scale(&self, k: &Fp<P, N>) -> Self {
        Fp2 { c0: self.c0.mul(k), c1: self.c1.mul(k) }
    }
}

impl<P: FieldParams<N>, const N: usize> std::fmt::Debug for Fp2<P, N> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "({:?} + {:?}*u)", self.c0, self.c1)
    }
}

impl<P: FieldParams<N>, const N: usize> Field for Fp2<P, N> {
    fn zero() -> Self {
        Fp2 { c0: Fp::zero(), c1: Fp::zero() }
    }

    fn one() -> Self {
        Fp2 { c0: Fp::one(), c1: Fp::zero() }
    }

    fn is_zero(&self) -> bool {
        self.c0.is_zero() && self.c1.is_zero()
    }

    fn add(&self, o: &Self) -> Self {
        Fp2 { c0: self.c0.add(&o.c0), c1: self.c1.add(&o.c1) }
    }

    fn sub(&self, o: &Self) -> Self {
        Fp2 { c0: self.c0.sub(&o.c0), c1: self.c1.sub(&o.c1) }
    }

    fn neg(&self) -> Self {
        Fp2 { c0: self.c0.neg(), c1: self.c1.neg() }
    }

    fn mul(&self, o: &Self) -> Self {
        // Karatsuba over u² = −1:
        //   v0 = a0·b0, v1 = a1·b1
        //   c0 = v0 − v1
        //   c1 = (a0+a1)(b0+b1) − v0 − v1
        let v0 = self.c0.mul(&o.c0);
        let v1 = self.c1.mul(&o.c1);
        let s = self.c0.add(&self.c1).mul(&o.c0.add(&o.c1));
        Fp2 { c0: v0.sub(&v1), c1: s.sub(&v0).sub(&v1) }
    }

    fn square(&self) -> Self {
        // (a0+a1·u)² with u²=−1: c0 = (a0+a1)(a0−a1), c1 = 2·a0·a1
        let t0 = self.c0.add(&self.c1);
        let t1 = self.c0.sub(&self.c1);
        let c1 = self.c0.mul(&self.c1).double();
        Fp2 { c0: t0.mul(&t1), c1 }
    }

    fn inv(&self) -> Option<Self> {
        // a⁻¹ = ā / N(a)
        let n = self.norm();
        let ninv = n.inv()?;
        Some(Fp2 { c0: self.c0.mul(&ninv), c1: self.c1.neg().mul(&ninv) })
    }

    fn from_u64(v: u64) -> Self {
        Fp2::from_base(Fp::from_u64(v))
    }

    fn random(rng: &mut Rng) -> Self {
        Fp2 { c0: Fp::random(rng), c1: Fp::random(rng) }
    }

    fn order_minus_one() -> Vec<u64> {
        // p² − 1 = (p−1)(p+1): multiply slices then no subtraction needed —
        // compute p² then subtract 1.
        let p = P::MODULUS.to_vec();
        let mut sq = vec![0u64; 2 * N];
        for i in 0..N {
            let mut carry = 0u64;
            for j in 0..N {
                let (lo, hi) = super::bigint::mac(sq[i + j], p[i], p[j], carry);
                sq[i + j] = lo;
                carry = hi;
            }
            sq[i + N] = carry;
        }
        // subtract 1 (p² is odd² = odd, so limb 0 ≥ 1)
        sq[0] -= 1;
        sq
    }
}

impl<P: FieldParams<N>, const N: usize> std::ops::Add for Fp2<P, N> {
    type Output = Self;
    fn add(self, rhs: Self) -> Self {
        Field::add(&self, &rhs)
    }
}
impl<P: FieldParams<N>, const N: usize> std::ops::Sub for Fp2<P, N> {
    type Output = Self;
    fn sub(self, rhs: Self) -> Self {
        Field::sub(&self, &rhs)
    }
}
impl<P: FieldParams<N>, const N: usize> std::ops::Mul for Fp2<P, N> {
    type Output = Self;
    fn mul(self, rhs: Self) -> Self {
        Field::mul(&self, &rhs)
    }
}
impl<P: FieldParams<N>, const N: usize> std::ops::Neg for Fp2<P, N> {
    type Output = Self;
    fn neg(self) -> Self {
        Field::neg(&self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::params::{Bls12381FpParams, Bn254FpParams};

    type F2Bn = Fp2<Bn254FpParams, 4>;
    type F2Bls = Fp2<Bls12381FpParams, 6>;

    #[test]
    fn u_squared_is_minus_one() {
        let u = F2Bn { c0: Fp::zero(), c1: Fp::one() };
        assert_eq!(u.square(), F2Bn::one().neg());
        let u = F2Bls { c0: Fp::zero(), c1: Fp::one() };
        assert_eq!(u.mul(&u), F2Bls::one().neg());
    }

    #[test]
    fn mul_matches_schoolbook() {
        let mut rng = Rng::new(21);
        for _ in 0..30 {
            let a = F2Bls::random(&mut rng);
            let b = F2Bls::random(&mut rng);
            // schoolbook: (a0b0 - a1b1) + (a0b1 + a1b0) u
            let c0 = a.c0.mul(&b.c0).sub(&a.c1.mul(&b.c1));
            let c1 = a.c0.mul(&b.c1).add(&a.c1.mul(&b.c0));
            assert_eq!(a.mul(&b), Fp2 { c0, c1 });
        }
    }

    #[test]
    fn square_matches_mul() {
        let mut rng = Rng::new(22);
        let a = F2Bn::random(&mut rng);
        assert_eq!(a.square(), a.mul(&a));
    }

    #[test]
    fn inverse() {
        let mut rng = Rng::new(23);
        for _ in 0..10 {
            let a = F2Bn::random(&mut rng);
            if a.is_zero() {
                continue;
            }
            assert_eq!(a.mul(&a.inv().unwrap()), F2Bn::one());
        }
        assert!(F2Bn::zero().inv().is_none());
    }

    #[test]
    fn norm_multiplicative() {
        let mut rng = Rng::new(24);
        let a = F2Bls::random(&mut rng);
        let b = F2Bls::random(&mut rng);
        assert_eq!(a.mul(&b).norm(), a.norm().mul(&b.norm()));
    }

    #[test]
    fn base_field_embeds() {
        let mut rng = Rng::new(25);
        let x = Fp::<Bn254FpParams, 4>::random(&mut rng);
        let y = Fp::<Bn254FpParams, 4>::random(&mut rng);
        let ex = F2Bn::from_base(x);
        let ey = F2Bn::from_base(y);
        assert_eq!(ex.mul(&ey), F2Bn::from_base(x.mul(&y)));
    }

    #[test]
    fn fermat_in_extension() {
        // a^(p²−1) = 1
        let mut rng = Rng::new(26);
        let a = F2Bn::random(&mut rng);
        let e = F2Bn::order_minus_one();
        assert_eq!(a.pow_limbs(&e), F2Bn::one());
    }
}
