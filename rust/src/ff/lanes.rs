//! 4-lane limb-interleaved Montgomery field core.
//!
//! The paper saturates its carry-save modular multipliers by keeping many
//! independent products in flight (§IV-B); the software analogue is ILP:
//! one CIOS pass per lane has a serial limb-carry chain, but **four
//! independent lanes have four independent carry chains**, so a scalar
//! CPU can overlap them and an autovectorizer can map the lane loop onto
//! SIMD multiply/add units. [`FpLanes`] stores 4 field elements in
//! structure-of-arrays layout — `mont[limb][lane]` — so the innermost
//! loop of every kernel walks lanes, not limbs, and carries never cross
//! lanes.
//!
//! **Determinism is structural**: each lane runs *exactly* the scalar
//! [`Fp`](super::Fp) algorithm (same CIOS multiply, same SOS squaring,
//! same final conditional subtraction, taken per lane on that lane's own
//! values), so lane results are bit-identical to the scalar reference by
//! construction — not by rounding luck. There is no cross-lane data flow
//! anywhere, hence no reassociation at all.
//!
//! Op accounting: lane ops charge the same [`super::opcount`] lanes as
//! four scalar ops (`mul4` counts 4 muls, `square4` 4 squares, …), so
//! every pinned budget in `tests/perf_smoke.rs` stays honest whether a
//! path runs scalar or vectorized.

use super::bigint::{self, adc, mac, sbb};
use super::fp::{FieldParams, Fp};
use super::opcount;
use std::marker::PhantomData;

/// Number of independent lanes the vectorized field core processes per
/// step. Fixed at 4: wide enough to cover the carry-chain latency of a
/// 64×64 multiply, narrow enough that ragged tails stay cheap.
pub const LANES: usize = 4;

/// Extract lane `l` of an interleaved limb matrix as a contiguous value.
#[inline]
fn column<const N: usize>(t: &[[u64; LANES]; N], l: usize) -> [u64; N] {
    let mut col = [0u64; N];
    for (j, c) in col.iter_mut().enumerate() {
        *c = t[j][l];
    }
    col
}

/// Write a contiguous value back into lane `l` of an interleaved matrix.
#[inline]
fn set_column<const N: usize>(t: &mut [[u64; LANES]; N], l: usize, col: &[u64; N]) {
    for (j, c) in col.iter().enumerate() {
        t[j][l] = *c;
    }
}

/// Four independent prime-field elements in limb-interleaved
/// (structure-of-arrays) Montgomery form: `mont[j][l]` is limb `j` of
/// lane `l`. See the module docs for the layout/ILP argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FpLanes<P: FieldParams<N>, const N: usize> {
    /// Interleaved Montgomery limbs, limb-major / lane-minor.
    mont: [[u64; LANES]; N],
    _p: PhantomData<P>,
}

impl<P: FieldParams<N>, const N: usize> FpLanes<P, N> {
    /// Word multiplications one [`Self::mul4`] issues: exactly 4 scalar
    /// CIOS multiplies, 4·[`Fp::MUL_WORD_MULS`].
    pub const MUL4_WORD_MULS: u64 = (LANES as u64) * Fp::<P, N>::MUL_WORD_MULS;
    /// Word multiplications one [`Self::square4`] issues: exactly 4
    /// scalar SOS squarings, 4·[`Fp::SQUARE_WORD_MULS`].
    pub const SQUARE4_WORD_MULS: u64 = (LANES as u64) * Fp::<P, N>::SQUARE_WORD_MULS;

    #[inline]
    fn from_mont(mont: [[u64; LANES]; N]) -> Self {
        FpLanes { mont, _p: PhantomData }
    }

    /// Interleave 4 scalar elements into lane form.
    #[inline]
    pub fn from_elems(xs: &[Fp<P, N>; LANES]) -> Self {
        let mut mont = [[0u64; LANES]; N];
        for (l, x) in xs.iter().enumerate() {
            for (j, row) in mont.iter_mut().enumerate() {
                row[l] = x.mont[j];
            }
        }
        Self::from_mont(mont)
    }

    /// De-interleave back to 4 scalar elements.
    #[inline]
    pub fn to_elems(&self) -> [Fp<P, N>; LANES] {
        std::array::from_fn(|l| Fp::from_mont(column(&self.mont, l)))
    }

    /// Broadcast one element into all 4 lanes.
    #[inline]
    pub fn splat(x: &Fp<P, N>) -> Self {
        let mut mont = [[0u64; LANES]; N];
        for (j, row) in mont.iter_mut().enumerate() {
            *row = [x.mont[j]; LANES];
        }
        Self::from_mont(mont)
    }

    /// Interleave the first [`LANES`] elements of a slice.
    ///
    /// # Panics
    /// If `xs.len() < LANES`.
    #[inline]
    pub fn load(xs: &[Fp<P, N>]) -> Self {
        let head: &[Fp<P, N>; LANES] = xs[..LANES].try_into().expect("load needs >= LANES");
        Self::from_elems(head)
    }

    /// De-interleave into the first [`LANES`] slots of a slice.
    ///
    /// # Panics
    /// If `out.len() < LANES`.
    #[inline]
    pub fn store(&self, out: &mut [Fp<P, N>]) {
        out[..LANES].copy_from_slice(&self.to_elems());
    }

    /// Per-lane conditional subtraction of p (values known < 2p).
    #[inline]
    fn reduce_once(mut t: [[u64; LANES]; N]) -> Self {
        for l in 0..LANES {
            let col = column(&t, l);
            if bigint::gte(&col, &P::MODULUS) {
                let (d, _) = bigint::sub(&col, &P::MODULUS);
                set_column(&mut t, l, &d);
            }
        }
        Self::from_mont(t)
    }

    /// 4 independent CIOS Montgomery multiplies. The limb schedule is the
    /// scalar [`Fp`] multiply verbatim; only the innermost dimension (the
    /// lane walk) is new, and its 4 carry chains are fully independent.
    #[inline]
    fn mul4_raw(a: &[[u64; LANES]; N], b: &[[u64; LANES]; N]) -> [[u64; LANES]; N] {
        let mut t = [[0u64; LANES]; N];
        let mut t_n = [0u64; LANES]; // t[N] per lane
        let mut t_n1 = [0u64; LANES]; // t[N+1] per lane, 0 or 1
        for i in 0..N {
            // t += a[i] * b, per lane
            let mut carry = [0u64; LANES];
            for j in 0..N {
                for l in 0..LANES {
                    let (lo, hi) = mac(t[j][l], a[i][l], b[j][l], carry[l]);
                    t[j][l] = lo;
                    carry[l] = hi;
                }
            }
            for l in 0..LANES {
                let (s, c) = adc(t_n[l], carry[l], 0);
                t_n[l] = s;
                t_n1[l] = c;
            }

            // m = t[0] · (−p⁻¹) mod 2⁶⁴ ; t += m·p ; t >>= 64, per lane
            let mut m = [0u64; LANES];
            let mut carry = [0u64; LANES];
            for l in 0..LANES {
                m[l] = t[0][l].wrapping_mul(Fp::<P, N>::INV);
                let (_, hi) = mac(t[0][l], m[l], P::MODULUS[0], 0);
                carry[l] = hi;
            }
            for j in 1..N {
                for l in 0..LANES {
                    let (lo, hi) = mac(t[j][l], m[l], P::MODULUS[j], carry[l]);
                    t[j - 1][l] = lo;
                    carry[l] = hi;
                }
            }
            for l in 0..LANES {
                let (s, c) = adc(t_n[l], carry[l], 0);
                t[N - 1][l] = s;
                t_n[l] = t_n1[l] + c;
            }
        }
        // Final conditional subtraction is data-dependent per lane —
        // taken on each lane's own value, exactly like the scalar path.
        for l in 0..LANES {
            let col = column(&t, l);
            if t_n[l] > 0 || bigint::gte(&col, &P::MODULUS) {
                let (d, _) = bigint::sub(&col, &P::MODULUS);
                set_column(&mut t, l, &d);
            }
        }
        t
    }

    /// 4 independent SOS Montgomery squarings (scalar schedule per lane:
    /// upper-triangle cross terms, one-bit shift doubling, diagonal,
    /// word-by-word reduction).
    #[inline]
    fn square4_raw(a: &[[u64; LANES]; N]) -> [[u64; LANES]; N] {
        debug_assert!(2 * N <= 16, "SOS scratch supports N <= 8");
        let mut r = [[0u64; LANES]; 16];

        // Upper-triangle cross products a[i]·a[j], i < j, per lane.
        for i in 0..N {
            let mut carry = [0u64; LANES];
            for j in (i + 1)..N {
                for l in 0..LANES {
                    let (lo, hi) = mac(r[i + j][l], a[i][l], a[j][l], carry[l]);
                    r[i + j][l] = lo;
                    carry[l] = hi;
                }
            }
            r[i + N] = carry;
        }

        // Double the cross strip: one-bit left shift across 2N limbs.
        for l in 0..LANES {
            r[2 * N - 1][l] = r[2 * N - 2][l] >> 63;
        }
        for i in (2..=(2 * N - 2)).rev() {
            for l in 0..LANES {
                r[i][l] = (r[i][l] << 1) | (r[i - 1][l] >> 63);
            }
        }
        for l in 0..LANES {
            r[1][l] <<= 1;
        }

        // Add the diagonal a[i]², per lane.
        let mut carry = [0u64; LANES];
        for i in 0..N {
            for l in 0..LANES {
                let (lo, hi) = mac(r[2 * i][l], a[i][l], a[i][l], carry[l]);
                r[2 * i][l] = lo;
                let (s, c) = adc(r[2 * i + 1][l], hi, 0);
                r[2 * i + 1][l] = s;
                carry[l] = c;
            }
        }
        debug_assert_eq!(carry, [0u64; LANES], "a^2 fits 2N limbs");

        // Word-by-word Montgomery reduction of the 2N-limb squares.
        let mut carry2 = [0u64; LANES];
        for i in 0..N {
            let mut m = [0u64; LANES];
            let mut carry = [0u64; LANES];
            for l in 0..LANES {
                m[l] = r[i][l].wrapping_mul(Fp::<P, N>::INV);
                let (_, hi) = mac(r[i][l], m[l], P::MODULUS[0], 0);
                carry[l] = hi;
            }
            for j in 1..N {
                for l in 0..LANES {
                    let (lo, hi) = mac(r[i + j][l], m[l], P::MODULUS[j], carry[l]);
                    r[i + j][l] = lo;
                    carry[l] = hi;
                }
            }
            for l in 0..LANES {
                let (s, c) = adc(r[i + N][l], carry2[l], carry[l]);
                r[i + N][l] = s;
                carry2[l] = c;
            }
        }
        debug_assert_eq!(carry2, [0u64; LANES]);

        let mut out = [[0u64; LANES]; N];
        for (j, row) in out.iter_mut().enumerate() {
            *row = r[j + N];
        }
        for l in 0..LANES {
            let col = column(&out, l);
            if bigint::gte(&col, &P::MODULUS) {
                let (d, _) = bigint::sub(&col, &P::MODULUS);
                set_column(&mut out, l, &d);
            }
        }
        out
    }

    /// 4 independent field multiplications (counts 4 muls).
    #[inline]
    pub fn mul4(&self, rhs: &Self) -> Self {
        opcount::count_muls(LANES as u64);
        Self::from_mont(Self::mul4_raw(&self.mont, &rhs.mont))
    }

    /// 4 independent field squarings (counts 4 squares).
    #[inline]
    pub fn square4(&self) -> Self {
        opcount::count_squares(LANES as u64);
        Self::from_mont(Self::square4_raw(&self.mont))
    }

    /// 4 independent field additions (counts 4 adds).
    #[inline]
    pub fn add4(&self, rhs: &Self) -> Self {
        opcount::count_adds(LANES as u64);
        let mut s = [[0u64; LANES]; N];
        let mut carry = [0u64; LANES];
        for j in 0..N {
            for l in 0..LANES {
                let (x, c) = adc(self.mont[j][l], rhs.mont[j][l], carry[l]);
                s[j][l] = x;
                carry[l] = c;
            }
        }
        // Both operands < p < 2^(64N−1) ⇒ no carry-out possible.
        debug_assert_eq!(carry, [0u64; LANES]);
        Self::reduce_once(s)
    }

    /// 4 independent field subtractions (counts 4 adds).
    #[inline]
    pub fn sub4(&self, rhs: &Self) -> Self {
        opcount::count_adds(LANES as u64);
        let mut d = [[0u64; LANES]; N];
        let mut borrow = [0u64; LANES];
        for j in 0..N {
            for l in 0..LANES {
                let (x, b) = sbb(self.mont[j][l], rhs.mont[j][l], borrow[l]);
                d[j][l] = x;
                borrow[l] = b;
            }
        }
        // Lanes that borrowed wrap back by adding p — per lane, exactly
        // the scalar sub's correction.
        for l in 0..LANES {
            if borrow[l] == 1 {
                let col = column(&d, l);
                let (r, _) = bigint::add(&col, &P::MODULUS);
                set_column(&mut d, l, &r);
            }
        }
        Self::from_mont(d)
    }

    /// 4 independent field doublings (counts 4 adds).
    #[inline]
    pub fn double4(&self) -> Self {
        opcount::count_adds(LANES as u64);
        let mut s = [[0u64; LANES]; N];
        for j in (1..N).rev() {
            for l in 0..LANES {
                s[j][l] = (self.mont[j][l] << 1) | (self.mont[j - 1][l] >> 63);
            }
        }
        for l in 0..LANES {
            s[0][l] = self.mont[0][l] << 1;
        }
        // Values < p < 2^(64N−1): the shifted top bit is always zero.
        Self::reduce_once(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::params::{Bls12381FpParams, Bn254FpParams, Bn254FrParams};
    use crate::ff::Field;
    use crate::util::rng::Rng;

    type FpBn = Fp<Bn254FpParams, 4>;
    type FpBls = Fp<Bls12381FpParams, 6>;

    fn quad<F: Field>(rng: &mut Rng) -> [F; LANES] {
        std::array::from_fn(|_| F::random(rng))
    }

    fn check_all_ops<P: FieldParams<N>, const N: usize>(
        a: &[Fp<P, N>; LANES],
        b: &[Fp<P, N>; LANES],
    ) {
        let av = FpLanes::from_elems(a);
        let bv = FpLanes::from_elems(b);
        let mul = av.mul4(&bv).to_elems();
        let sq = av.square4().to_elems();
        let add = av.add4(&bv).to_elems();
        let sub = av.sub4(&bv).to_elems();
        let dbl = av.double4().to_elems();
        for l in 0..LANES {
            assert_eq!(mul[l], a[l].mul(&b[l]), "{} mul lane {l}", P::NAME);
            assert_eq!(sq[l], a[l].square(), "{} square lane {l}", P::NAME);
            assert_eq!(add[l], a[l].add(&b[l]), "{} add lane {l}", P::NAME);
            assert_eq!(sub[l], a[l].sub(&b[l]), "{} sub lane {l}", P::NAME);
            assert_eq!(dbl[l], Field::double(&a[l]), "{} double lane {l}", P::NAME);
        }
    }

    #[test]
    fn lanes_match_scalar_random() {
        let mut rng = Rng::new(0xA1);
        for _ in 0..100 {
            check_all_ops::<Bn254FpParams, 4>(&quad(&mut rng), &quad(&mut rng));
            check_all_ops::<Bn254FrParams, 4>(&quad(&mut rng), &quad(&mut rng));
            check_all_ops::<Bls12381FpParams, 6>(&quad(&mut rng), &quad(&mut rng));
        }
    }

    #[test]
    fn lanes_match_scalar_edges() {
        // mixed edge/random lanes stress the per-lane conditional
        // subtraction: each lane must take its own branch
        fn edges<P: FieldParams<N>, const N: usize>() -> [Fp<P, N>; LANES] {
            [
                Fp::<P, N>::zero(),
                Fp::<P, N>::one(),
                Fp::<P, N>::one().neg(), // p − 1
                Fp::<P, N>::from_limbs_reduce([0x8000_0000_0000_0000u64; N]),
            ]
        }
        let mut rng = Rng::new(0xA2);
        check_all_ops::<Bn254FpParams, 4>(&edges(), &edges());
        check_all_ops::<Bls12381FpParams, 6>(&edges(), &edges());
        check_all_ops::<Bn254FpParams, 4>(&edges(), &quad(&mut rng));
        check_all_ops::<Bls12381FpParams, 6>(&quad(&mut rng), &edges());
    }

    #[test]
    fn interleave_roundtrip_and_splat() {
        let mut rng = Rng::new(0xA3);
        let xs: [FpBn; LANES] = quad(&mut rng);
        assert_eq!(FpLanes::from_elems(&xs).to_elems(), xs);
        let s = FpLanes::splat(&xs[2]).to_elems();
        assert_eq!(s, [xs[2]; LANES]);
        let mut out = [FpBn::zero(); LANES];
        FpLanes::load(&xs).store(&mut out);
        assert_eq!(out, xs);
    }

    #[test]
    fn lane_ops_count_like_four_scalar_ops() {
        let mut rng = Rng::new(0xA4);
        let a = FpLanes::<Bn254FpParams, 4>::from_elems(&quad(&mut rng));
        let b = FpLanes::from_elems(&quad(&mut rng));
        let (_, ops) = opcount::measure(|| {
            let m = a.mul4(&b);
            let s = m.square4();
            s.add4(&b).sub4(&a).double4()
        });
        assert_eq!(ops.mul, 4);
        assert_eq!(ops.square, 4);
        assert_eq!(ops.add, 12);
    }

    #[test]
    fn word_mul_consts_are_four_scalar_budgets() {
        assert_eq!(FpLanes::<Bn254FpParams, 4>::MUL4_WORD_MULS, 4 * FpBn::MUL_WORD_MULS);
        assert_eq!(FpLanes::<Bn254FpParams, 4>::SQUARE4_WORD_MULS, 4 * FpBn::SQUARE_WORD_MULS);
        assert_eq!(FpLanes::<Bls12381FpParams, 6>::MUL4_WORD_MULS, 4 * FpBls::MUL_WORD_MULS);
        assert_eq!(FpLanes::<Bls12381FpParams, 6>::SQUARE4_WORD_MULS, 4 * FpBls::SQUARE_WORD_MULS);
    }
}
