//! Repacking between the host's 64-bit limbs and the engine's 16-bit limbs.
//!
//! The L1 Pallas kernel computes Montgomery arithmetic over 16-bit limbs
//! (chosen so all delayed-carry accumulations fit u64 — the software
//! analogue of the paper's carry-save LUT reduction, §IV-B1/B4). Because
//! the kernel's radix satisfies `R16 = 2^(16·4N) = 2^(64·N) = R64`, an
//! element's **Montgomery representation is identical in both domains**;
//! converting is pure limb-splitting with no arithmetic.

/// Split little-endian u64 limbs into 4× as many 16-bit limbs (stored u32,
/// the engine's I/O dtype).
pub fn u64_to_u16_limbs(limbs: &[u64]) -> Vec<u32> {
    let mut out = Vec::with_capacity(limbs.len() * 4);
    for &l in limbs {
        out.push((l & 0xFFFF) as u32);
        out.push(((l >> 16) & 0xFFFF) as u32);
        out.push(((l >> 32) & 0xFFFF) as u32);
        out.push(((l >> 48) & 0xFFFF) as u32);
    }
    out
}

/// Inverse of [`u64_to_u16_limbs`]. `u16s.len()` must be a multiple of 4 and
/// each entry must fit in 16 bits.
pub fn u16_limbs_to_u64(u16s: &[u32]) -> Result<Vec<u64>, String> {
    if u16s.len() % 4 != 0 {
        return Err(format!("16-bit limb count {} not a multiple of 4", u16s.len()));
    }
    let mut out = Vec::with_capacity(u16s.len() / 4);
    for chunk in u16s.chunks_exact(4) {
        for &v in chunk {
            if v > 0xFFFF {
                return Err(format!("limb value {v:#x} exceeds 16 bits"));
            }
        }
        out.push(
            chunk[0] as u64
                | (chunk[1] as u64) << 16
                | (chunk[2] as u64) << 32
                | (chunk[3] as u64) << 48,
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_random() {
        let mut rng = Rng::new(41);
        for n in [1usize, 4, 6] {
            let limbs = rng.words(n);
            let u16s = u64_to_u16_limbs(&limbs);
            assert_eq!(u16s.len(), 4 * n);
            assert!(u16s.iter().all(|&v| v <= 0xFFFF));
            assert_eq!(u16_limbs_to_u64(&u16s).unwrap(), limbs);
        }
    }

    #[test]
    fn known_value() {
        let u16s = u64_to_u16_limbs(&[0x0123_4567_89ab_cdef]);
        assert_eq!(u16s, vec![0xcdef, 0x89ab, 0x4567, 0x0123]);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(u16_limbs_to_u64(&[1, 2, 3]).is_err());
        assert!(u16_limbs_to_u64(&[0x10000, 0, 0, 0]).is_err());
    }
}
