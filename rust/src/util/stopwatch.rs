//! Wall-clock instrumentation mirroring the paper's methodology (§V-A):
//! "a high-resolution stopwatch on the host side" plus named accumulating
//! timers for the prover profiling breakdown (Table I).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Time since [`Stopwatch::start`] (or the last restart).
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed time as fractional seconds.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Return the elapsed time and reset the start point to now.
    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates time under string labels — the instrumentation used to
/// regenerate the paper's Table I prover breakdown.
#[derive(Debug, Default)]
pub struct Profiler {
    acc: BTreeMap<String, Duration>,
}

impl Profiler {
    /// Empty profiler.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `label`.
    pub fn time<T>(&mut self, label: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(label, t0.elapsed());
        out
    }

    /// Add externally-measured time under `label`.
    pub fn add(&mut self, label: &str, d: Duration) {
        *self.acc.entry(label.to_string()).or_default() += d;
    }

    /// Total accumulated time across all labels.
    pub fn total(&self) -> Duration {
        self.acc.values().sum()
    }

    /// Accumulated time under one label (zero when unseen).
    pub fn get(&self, label: &str) -> Duration {
        self.acc.get(label).copied().unwrap_or_default()
    }

    /// Percentage breakdown (label → % of total), the Table I format.
    pub fn percentages(&self) -> Vec<(String, f64)> {
        let total = self.total().as_secs_f64();
        self.acc
            .iter()
            .map(|(k, v)| {
                let pct = if total > 0.0 {
                    100.0 * v.as_secs_f64() / total
                } else {
                    0.0
                };
                (k.clone(), pct)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.secs() >= 0.002);
    }

    #[test]
    fn profiler_accumulates() {
        let mut p = Profiler::new();
        p.add("msm_g1", Duration::from_millis(30));
        p.add("msm_g1", Duration::from_millis(30));
        p.add("ntt", Duration::from_millis(40));
        assert_eq!(p.get("msm_g1"), Duration::from_millis(60));
        let pct = p.percentages();
        let g1 = pct.iter().find(|(k, _)| k == "msm_g1").unwrap().1;
        assert!((g1 - 60.0).abs() < 1.0);
    }

    #[test]
    fn profiler_time_closure() {
        let mut p = Profiler::new();
        let v = p.time("work", || 7);
        assert_eq!(v, 7);
        assert!(p.total() > Duration::ZERO);
    }
}
