//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! A property is a closure `FnMut(&mut Rng) -> Result<(), String>` run for a
//! configurable number of cases with deterministic per-case seeds; on
//! failure the harness reports the case index and seed so the exact case can
//! be replayed with `check_seeded`.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of cases to generate and check.
    pub cases: usize,
    /// Base seed; each case derives its own deterministic seed from it.
    pub seed: u64,
}

impl Config {
    /// Fixed default seed, recorded so failures are reproducible.
    pub const DEFAULT_SEED: u64 = 0x1f2b_a5e5_eed5_2024;
}

impl Default for Config {
    fn default() -> Self {
        // IFZKP_PROP_CASES scales CI effort without touching code.
        let cases = std::env::var("IFZKP_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        Config { cases, seed: Config::DEFAULT_SEED }
    }
}

/// Run `prop` for `cfg.cases` cases; panic with diagnostics on failure.
pub fn check_with(cfg: Config, name: &str, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{} (replay seed {case_seed:#x}): {msg}",
                cfg.cases
            );
        }
    }
}

/// Run with default config.
pub fn check(name: &str, prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    check_with(Config::default(), name, prop)
}

/// Replay a single case by seed (for debugging a reported failure).
pub fn check_seeded(seed: u64, name: &str, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed for seed {seed:#x}: {msg}");
    }
}

/// Assertion helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

/// Equality helper producing a useful message.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("u64 addition commutes", |rng| {
            let (a, b) = (rng.next_u64() >> 1, rng.next_u64() >> 1);
            prop_assert!(a + b == b + a, "{a} + {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn failing_property_panics_with_seed() {
        check("always fails", |_| Err("always fails".into()));
    }

    #[test]
    fn deterministic_cases() {
        let mut seen1 = Vec::new();
        check_with(Config { cases: 5, seed: 1 }, "collect", |rng| {
            seen1.push(rng.next_u64());
            Ok(())
        });
        let mut seen2 = Vec::new();
        check_with(Config { cases: 5, seed: 1 }, "collect", |rng| {
            seen2.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen1, seen2);
    }
}
