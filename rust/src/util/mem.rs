//! Memory-budget accounting for the streaming prover.
//!
//! The paper's accelerator streams point/scalar chunks from DDR precisely
//! because the full MSM working set does not fit on-chip; the host-side
//! streaming pipeline (`msm::stream`, `snark::stream`) makes the same move
//! against host RAM and needs the budget to be *enforced*, not advisory.
//! [`MemLedger`] is that enforcement point: every streamed chunk charges
//! its payload bytes before the chunk is read and credits them (RAII) when
//! the chunk is dropped, so the accounted high-water mark
//! ([`MemLedger::peak_bytes`]) provably never exceeds the configured
//! [`MemoryBudget`] — a charge that would exceed it fails with a typed
//! [`BudgetExceeded`] instead.
//!
//! Two lanes, deliberately separate:
//!
//! * **chunk lane** (`charge`/[`MemCharge`]) — transient streamed bytes,
//!   capped by the budget; this is the lane `tests/perf_smoke.rs` pins.
//! * **fixed lane** ([`MemLedger::note_fixed`]) — Θ(m) inputs the
//!   streaming path still holds resident (the witness values, the QAP's
//!   h coefficients). Tracked and reported, never capped: the streaming
//!   guarantee is "peak ≤ budget + fixed", and the fixed term is pinned
//!   exactly so it cannot silently absorb chunk traffic.
//!
//! Executor scratch (bucket arrays, the digit matrix) is a deterministic
//! function of chunk size and plan — bounded by the same budget choice —
//! and is accounted by the plan layer, not here (see DESIGN.md
//! "Streaming prover" for the accounting rule).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// Number of bytes one canonical scalar occupies in a streamed chunk
/// (`ScalarLimbs = [u64; 4]`).
pub const SCALAR_BYTES: u64 = 32;

/// A peak-resident-bytes cap for the streaming pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct MemoryBudget {
    bytes: u64,
}

impl MemoryBudget {
    /// A budget of exactly `bytes` bytes.
    pub const fn bytes(bytes: u64) -> Self {
        MemoryBudget { bytes }
    }

    /// A budget of `mib` mebibytes.
    pub const fn mib(mib: u64) -> Self {
        MemoryBudget { bytes: mib << 20 }
    }

    /// No cap (`u64::MAX` bytes) — accounting only.
    pub const fn unlimited() -> Self {
        MemoryBudget { bytes: u64::MAX }
    }

    /// The cap in bytes.
    pub const fn get(&self) -> u64 {
        self.bytes
    }

    /// Is this the uncapped sentinel?
    pub const fn is_unlimited(&self) -> bool {
        self.bytes == u64::MAX
    }
}

/// Typed refusal from [`MemLedger::charge`]: admitting `requested` more
/// bytes on top of `live` would exceed `budget`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BudgetExceeded {
    /// Bytes the refused charge asked for.
    pub requested: u64,
    /// Live (already charged) bytes at refusal time.
    pub live: u64,
    /// The configured cap.
    pub budget: u64,
}

impl fmt::Display for BudgetExceeded {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "memory budget exceeded: charging {} bytes over {} live would pass the {}-byte budget",
            self.requested, self.live, self.budget
        )
    }
}

impl std::error::Error for BudgetExceeded {}

/// Live/peak/fixed byte accounting with an enforced budget on the chunk
/// lane. Thread-safe: charges are atomic, so parallel streams sharing one
/// ledger stay within the one budget collectively.
#[derive(Debug)]
pub struct MemLedger {
    budget: MemoryBudget,
    live: AtomicU64,
    peak: AtomicU64,
    fixed: AtomicU64,
}

impl MemLedger {
    /// A ledger enforcing `budget` on the chunk lane.
    pub fn new(budget: MemoryBudget) -> Self {
        MemLedger {
            budget,
            live: AtomicU64::new(0),
            peak: AtomicU64::new(0),
            fixed: AtomicU64::new(0),
        }
    }

    /// An accounting-only ledger (unlimited budget).
    pub fn unlimited() -> Self {
        MemLedger::new(MemoryBudget::unlimited())
    }

    /// The configured budget.
    pub fn budget(&self) -> MemoryBudget {
        self.budget
    }

    /// Charge `bytes` to the chunk lane, failing (without side effects) if
    /// the budget would be exceeded. The returned guard credits the bytes
    /// back when dropped, so a chunk's accounting lifetime is exactly its
    /// ownership lifetime — early returns and errors can never leak a
    /// charge.
    pub fn charge(&self, bytes: u64) -> Result<MemCharge<'_>, BudgetExceeded> {
        let mut cur = self.live.load(Ordering::SeqCst);
        loop {
            let next = cur.saturating_add(bytes);
            if next > self.budget.get() {
                return Err(BudgetExceeded {
                    requested: bytes,
                    live: cur,
                    budget: self.budget.get(),
                });
            }
            match self.live.compare_exchange(cur, next, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => {
                    self.peak.fetch_max(next, Ordering::SeqCst);
                    return Ok(MemCharge { ledger: self, bytes });
                }
                Err(seen) => cur = seen,
            }
        }
    }

    /// Record `bytes` of Θ(m) resident input on the (uncapped) fixed lane.
    pub fn note_fixed(&self, bytes: u64) {
        self.fixed.fetch_add(bytes, Ordering::SeqCst);
    }

    /// Currently charged chunk bytes.
    pub fn live_bytes(&self) -> u64 {
        self.live.load(Ordering::SeqCst)
    }

    /// High-water mark of the chunk lane — never exceeds the budget.
    pub fn peak_bytes(&self) -> u64 {
        self.peak.load(Ordering::SeqCst)
    }

    /// Total bytes recorded on the fixed lane.
    pub fn fixed_bytes(&self) -> u64 {
        self.fixed.load(Ordering::SeqCst)
    }
}

/// RAII guard for one chunk-lane charge (see [`MemLedger::charge`]).
#[derive(Debug)]
pub struct MemCharge<'a> {
    ledger: &'a MemLedger,
    bytes: u64,
}

impl MemCharge<'_> {
    /// Bytes this charge holds.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

impl Drop for MemCharge<'_> {
    fn drop(&mut self) {
        self.ledger.live.fetch_sub(self.bytes, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_credit_and_peak() {
        let l = MemLedger::new(MemoryBudget::bytes(1000));
        let a = l.charge(400).unwrap();
        assert_eq!(l.live_bytes(), 400);
        let b = l.charge(600).unwrap();
        assert_eq!(l.live_bytes(), 1000);
        assert_eq!(l.peak_bytes(), 1000);
        drop(a);
        assert_eq!(l.live_bytes(), 600);
        drop(b);
        assert_eq!(l.live_bytes(), 0);
        // peak is a high-water mark: credits never lower it
        assert_eq!(l.peak_bytes(), 1000);
    }

    #[test]
    fn budget_is_enforced_exactly() {
        let l = MemLedger::new(MemoryBudget::bytes(100));
        let _a = l.charge(60).unwrap();
        let err = l.charge(41).unwrap_err();
        assert_eq!(err, BudgetExceeded { requested: 41, live: 60, budget: 100 });
        // the refused charge left no trace
        assert_eq!(l.live_bytes(), 60);
        assert_eq!(l.peak_bytes(), 60);
        // the exact boundary is admitted
        let _b = l.charge(40).unwrap();
        assert_eq!(l.peak_bytes(), 100);
    }

    #[test]
    fn fixed_lane_is_tracked_but_uncapped() {
        let l = MemLedger::new(MemoryBudget::bytes(10));
        l.note_fixed(1 << 30);
        l.note_fixed(12);
        assert_eq!(l.fixed_bytes(), (1 << 30) + 12);
        // the chunk lane is unaffected by fixed notes
        assert_eq!(l.live_bytes(), 0);
        assert!(l.charge(11).is_err());
        assert!(l.charge(10).is_ok());
    }

    #[test]
    fn unlimited_never_refuses() {
        let l = MemLedger::unlimited();
        assert!(l.budget().is_unlimited());
        let _a = l.charge(u64::MAX / 2).unwrap();
        let _b = l.charge(u64::MAX / 2).unwrap();
        assert!(l.charge(u64::MAX).is_ok());
    }

    #[test]
    fn budget_constructors() {
        assert_eq!(MemoryBudget::mib(2).get(), 2 << 20);
        assert_eq!(MemoryBudget::bytes(7).get(), 7);
        assert!(!MemoryBudget::bytes(7).is_unlimited());
        assert!(MemoryBudget::mib(1) < MemoryBudget::mib(2));
    }

    #[test]
    fn error_displays_the_numbers() {
        let e = BudgetExceeded { requested: 5, live: 9, budget: 12 };
        let s = e.to_string();
        assert!(s.contains('5') && s.contains('9') && s.contains("12"), "{s}");
    }
}
