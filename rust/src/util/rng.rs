//! Deterministic, seedable pseudo-random generator.
//!
//! xoshiro256** seeded through SplitMix64 — the standard construction used
//! by `rand_xoshiro`. Deterministic across platforms, which matters because
//! every test vector, synthetic workload and property-test case in the repo
//! is derived from an explicit seed recorded in `EXPERIMENTS.md`.

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let wide = (x as u128) * (bound as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo < bound {
                // slow path: reject the biased low region
                let threshold = bound.wrapping_neg() % bound;
                if lo < threshold {
                    continue;
                }
            }
            return hi;
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fair coin.
    #[inline]
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// `n` raw words.
    pub fn words(&mut self, n: usize) -> Vec<u64> {
        (0..n).map(|_| self.next_u64()).collect()
    }

    /// Fork a child generator (for parallel deterministic streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut c1 = root.fork(1);
        let mut c2 = root.fork(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
