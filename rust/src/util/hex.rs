//! Hex codecs for big integers stored as little-endian `u64` limb vectors.

/// Parse a (optionally `0x`-prefixed) big-endian hex string into `n` little-
/// endian u64 limbs. Errors if the value needs more than `n` limbs or
/// contains a non-hex character.
pub fn hex_to_limbs(s: &str, n: usize) -> Result<Vec<u64>, String> {
    let s = s.trim().trim_start_matches("0x").trim_start_matches("0X");
    if s.is_empty() {
        return Err("empty hex string".into());
    }
    let mut limbs = vec![0u64; n];
    // Walk nibbles from the least-significant end ('_' separators skipped
    // *before* positions are assigned).
    for (i, c) in s.bytes().rev().filter(|&c| c != b'_').enumerate() {
        let v = match c {
            b'0'..=b'9' => (c - b'0') as u64,
            b'a'..=b'f' => (c - b'a' + 10) as u64,
            b'A'..=b'F' => (c - b'A' + 10) as u64,
            _ => return Err(format!("invalid hex char {:?}", c as char)),
        };
        let limb = i / 16;
        if limb >= n {
            if v != 0 {
                return Err(format!("hex value does not fit in {n} limbs"));
            }
            continue;
        }
        limbs[limb] |= v << (4 * (i % 16));
    }
    Ok(limbs)
}

/// Render little-endian limbs as a `0x…` big-endian hex string without
/// leading zeros (but at least one digit).
pub fn limbs_to_hex(limbs: &[u64]) -> String {
    let mut s = String::new();
    let mut started = false;
    for &l in limbs.iter().rev() {
        if started {
            s.push_str(&format!("{l:016x}"));
        } else if l != 0 {
            s.push_str(&format!("{l:x}"));
            started = true;
        }
    }
    if !started {
        s.push('0');
    }
    format!("0x{s}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let l = hex_to_limbs("0xdeadbeef", 2).unwrap();
        assert_eq!(l, vec![0xdeadbeef, 0]);
        assert_eq!(limbs_to_hex(&l), "0xdeadbeef");
    }

    #[test]
    fn roundtrip_multi_limb() {
        let h = "0x1a0111ea397fe69a4b1ba7b6434bacd764774b84f38512bf6730d2a0f6b0f6241eabfffeb153ffffb9feffffffffaaab";
        let l = hex_to_limbs(h, 6).unwrap();
        assert_eq!(limbs_to_hex(&l), h);
    }

    #[test]
    fn rejects_overflow() {
        assert!(hex_to_limbs("0x10000000000000000", 1).is_err());
        // leading zeros beyond capacity are fine
        assert!(hex_to_limbs("0x0000000000000000f", 1).is_ok());
    }

    #[test]
    fn rejects_garbage() {
        assert!(hex_to_limbs("0xzz", 1).is_err());
        assert!(hex_to_limbs("", 1).is_err());
    }

    #[test]
    fn zero_renders() {
        assert_eq!(limbs_to_hex(&[0, 0]), "0x0");
    }

    #[test]
    fn underscores_allowed() {
        assert_eq!(hex_to_limbs("0xdead_beef", 1).unwrap(), vec![0xdeadbeef]);
    }
}
