//! Small self-contained utilities.
//!
//! The offline build environment carries no `rand`, `serde`, `proptest` or
//! `criterion`, so the pieces of those crates the project needs are
//! implemented here from scratch: a seedable RNG ([`rng`]), a JSON emitter
//! ([`json`]), hex codecs ([`hex`]), wall-clock instrumentation
//! ([`stopwatch`]), a tiny leveled logger ([`log`]), a miniature
//! property-testing harness ([`prop`]) and the enforced memory-budget
//! ledger the streaming prover charges its chunks against ([`mem`]).

pub mod rng;
pub mod hex;
pub mod json;
pub mod stopwatch;
pub mod log;
pub mod prop;
pub mod mem;

pub use mem::{MemLedger, MemoryBudget};
pub use rng::Rng;
pub use stopwatch::Stopwatch;

/// Format a point count like the paper's axes: `1K`, `64M`, …
pub fn human_count(n: u64) -> String {
    if n >= 1_000_000 && n % 1_000_000 == 0 {
        format!("{}M", n / 1_000_000)
    } else if n >= 1_000 && n % 1_000 == 0 {
        format!("{}K", n / 1_000)
    } else {
        n.to_string()
    }
}

/// Format seconds with adaptive precision (matches the paper's tables).
pub fn human_secs(s: f64) -> String {
    if s < 0.001 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_count_formats() {
        assert_eq!(human_count(1_000), "1K");
        assert_eq!(human_count(64_000_000), "64M");
        assert_eq!(human_count(123), "123");
        assert_eq!(human_count(1_500), "1500"); // not a round K
    }

    #[test]
    fn human_secs_formats() {
        assert_eq!(human_secs(2.5), "2.50s");
        assert_eq!(human_secs(0.0021), "2.10ms");
        assert_eq!(human_secs(0.0000005), "0.5us");
    }
}
