//! Tiny leveled stderr logger (the `log` crate facade is unavailable at the
//! versions our offline deps pin; this is all the project needs).

use std::sync::atomic::{AtomicU8, Ordering};

/// Log verbosity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable problems only.
    Error = 0,
    /// Degraded-but-continuing conditions.
    Warn = 1,
    /// Normal operational messages (the default).
    Info = 2,
    /// Verbose diagnostics.
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global level (e.g. from `--log-level` or `IFZKP_LOG`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Parse a level name.
pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" | "warning" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        _ => None,
    }
}

/// Is `level` currently emitted?
pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Emit one line to stderr when `level` is enabled (prefer the `info!`,
/// `warn_!`, `debug!` macros).
pub fn log(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {target}: {msg}");
    }
}

/// Log at [`Level::Info`] under a target tag.
#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target, format_args!($($arg)*))
    };
}

/// Log at [`Level::Warn`] (named `warn_!` to dodge the built-in lint name).
#[macro_export]
macro_rules! warn_ {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target, format_args!($($arg)*))
    };
}

/// Log at [`Level::Debug`] under a target tag.
#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("WARN"), Some(Level::Warn));
        assert_eq!(parse_level("nope"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
