//! Minimal JSON value + emitter (serde is unavailable offline).
//!
//! Only what the metrics/report paths need: construction, stable-order
//! object emission, and pretty printing. No parser — artifacts manifests are
//! written by python and read via [`parse`] which handles the small subset
//! `aot.py` emits (flat objects of strings/numbers/arrays).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (emitted integer-like when it has no fraction).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (stable key order via `BTreeMap`).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// An empty object, ready for [`Json::set`].
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-object — programmer error).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Object field lookup (`None` off objects or for missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn fmt_num(n: f64) -> String {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        format!("{}", n as i64)
    } else {
        format!("{n}")
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_compact(self, &mut s);
        f.write_str(&s)
    }
}

fn write_compact(j: &Json, out: &mut String) {
    match j {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => out.push_str(&fmt_num(*n)),
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_compact(v, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_compact(v, out);
            }
            out.push('}');
        }
    }
}

/// Parse a JSON document (full grammar, recursive descent). Used for the
/// artifact manifest written by `python/compile/aot.py`.
pub fn parse(src: &str) -> Result<Json, String> {
    let bytes = src.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing data at byte {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'{' => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos)? {
                    Json::Str(s) => s,
                    _ => return Err("object key must be string".into()),
                };
                skip_ws(b, pos);
                if *pos >= b.len() || b[*pos] != b':' {
                    return Err("expected ':'".into());
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                m.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(m));
                    }
                    _ => return Err("expected ',' or '}'".into()),
                }
            }
        }
        b'[' => {
            *pos += 1;
            let mut a = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(a));
            }
            loop {
                a.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(a));
                    }
                    _ => return Err("expected ',' or ']'".into()),
                }
            }
        }
        b'"' => {
            *pos += 1;
            let mut s = String::new();
            while *pos < b.len() {
                match b[*pos] {
                    b'"' => {
                        *pos += 1;
                        return Ok(Json::Str(s));
                    }
                    b'\\' => {
                        *pos += 1;
                        match b.get(*pos) {
                            Some(b'n') => s.push('\n'),
                            Some(b't') => s.push('\t'),
                            Some(b'r') => s.push('\r'),
                            Some(b'"') => s.push('"'),
                            Some(b'\\') => s.push('\\'),
                            Some(b'/') => s.push('/'),
                            Some(b'u') => {
                                let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                                    .map_err(|_| "bad \\u escape")?;
                                let cp =
                                    u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                                s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                                *pos += 4;
                            }
                            _ => return Err("bad escape".into()),
                        }
                        *pos += 1;
                    }
                    _ => {
                        // consume one UTF-8 scalar
                        let rest = std::str::from_utf8(&b[*pos..]).map_err(|_| "bad utf8")?;
                        let c = rest.chars().next().unwrap();
                        s.push(c);
                        *pos += c.len_utf8();
                    }
                }
            }
            Err("unterminated string".into())
        }
        b't' if b[*pos..].starts_with(b"true") => {
            *pos += 4;
            Ok(Json::Bool(true))
        }
        b'f' if b[*pos..].starts_with(b"false") => {
            *pos += 5;
            Ok(Json::Bool(false))
        }
        b'n' if b[*pos..].starts_with(b"null") => {
            *pos += 4;
            Ok(Json::Null)
        }
        _ => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let s = std::str::from_utf8(&b[start..*pos]).map_err(|_| "bad number")?;
            s.parse::<f64>().map(Json::Num).map_err(|e| e.to_string())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emit_object_stable_order() {
        let mut j = Json::obj();
        j.set("b", 2u64).set("a", 1u64);
        assert_eq!(j.to_string(), r#"{"a":1,"b":2}"#);
    }

    #[test]
    fn emit_escapes() {
        assert_eq!(Json::Str("a\"b\n".into()).to_string(), r#""a\"b\n""#);
    }

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"name":"uda_bn254","batch":256,"limbs":[1,2,3],"ok":true,"x":null}"#;
        let j = parse(src).unwrap();
        assert_eq!(j.get("batch").unwrap().as_f64(), Some(256.0));
        assert_eq!(j.get("name").unwrap().as_str(), Some("uda_bn254"));
        assert_eq!(j.get("limbs").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a":{"b":[1,{"c":2.5}]}}"#).unwrap();
        let b = j.get("a").unwrap().get("b").unwrap().as_arr().unwrap();
        assert_eq!(b[1].get("c").unwrap().as_f64(), Some(2.5));
    }

    #[test]
    fn parse_rejects_trailing() {
        assert!(parse("{}x").is_err());
        assert!(parse("").is_err());
        assert!(parse("{\"a\":}").is_err());
    }

    #[test]
    fn parse_floats_and_negatives() {
        assert_eq!(parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
    }
}
