//! Structure-preserving synthetic CRS.
//!
//! Groth16's prover consumes query vectors of group elements whose *sizes*
//! determine the MSM workload: per-variable 𝔾₁ queries (A, B₁, L), a
//! per-variable 𝔾₂ query (B₂) and a per-degree 𝔾₁ query (H). This setup
//! generates deterministic distinct points of exactly those shapes. It
//! deliberately does **not** embed τ-power structure — no trusted setup,
//! no toxic waste, not sound as a SNARK — because Table I only depends on
//! the compute shape (documented in DESIGN.md §7).

use crate::ec::{points, Affine, Bls12381G1, Bls12381G2, Bn254G1, Bn254G2, CurveParams};

/// CRS query vectors for one curve family.
pub struct Crs<G1: CurveParams, G2: CurveParams> {
    /// Per-variable 𝔾₁ A-query.
    pub a_query: Vec<Affine<G1>>,
    /// Per-variable 𝔾₁ B-query.
    pub b1_query: Vec<Affine<G1>>,
    /// Per-variable 𝔾₁ L-query (private-witness section).
    pub l_query: Vec<Affine<G1>>,
    /// Per-variable 𝔾₂ query.
    pub b2_query: Vec<Affine<G2>>,
    /// Degree-indexed 𝔾₁ query for h(x).
    pub h_query: Vec<Affine<G1>>,
}

impl<G1: CurveParams, G2: CurveParams> Crs<G1, G2> {
    /// Build for `num_vars` variables and an h-query of `domain_n − 1`.
    pub fn synthesize(num_vars: usize, domain_n: usize, seed: u64) -> Self {
        Crs {
            a_query: points::generate_points_walk::<G1>(num_vars, seed ^ 0xA1),
            b1_query: points::generate_points_walk::<G1>(num_vars, seed ^ 0xB1),
            l_query: points::generate_points_walk::<G1>(num_vars, seed ^ 0x11),
            b2_query: points::generate_points_walk::<G2>(num_vars, seed ^ 0xB2),
            h_query: points::generate_points_walk::<G1>(domain_n.saturating_sub(1), seed ^ 0x41),
        }
    }
}

/// The BN254 family CRS.
pub type CrsBn254 = Crs<Bn254G1, Bn254G2>;
/// The BLS12-381 family CRS.
pub type CrsBls12381 = Crs<Bls12381G1, Bls12381G2>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_request() {
        let crs = CrsBn254::synthesize(100, 128, 7);
        assert_eq!(crs.a_query.len(), 100);
        assert_eq!(crs.b2_query.len(), 100);
        assert_eq!(crs.h_query.len(), 127);
    }

    #[test]
    fn queries_are_distinct_streams() {
        let crs = CrsBls12381::synthesize(10, 16, 8);
        assert_ne!(crs.a_query[0].x, crs.b1_query[0].x);
        assert_ne!(crs.a_query[0].x, crs.l_query[0].x);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = CrsBn254::synthesize(5, 8, 9);
        let b = CrsBn254::synthesize(5, 8, 9);
        assert_eq!(a.a_query[3].x, b.a_query[3].x);
    }
}
