//! R1CS → QAP: the NTT-heavy half of the prover (§II-D).
//!
//! Given constraint evaluations over an n-point domain, compute the
//! quotient polynomial h(x) = (A(x)·B(x) − C(x)) / Z(x):
//!
//! 1. iNTT the per-constraint evaluation vectors → coefficient form
//!    (3 inverse transforms);
//! 2. coset-NTT each back to evaluations on g·⟨ω⟩ (3 forward transforms);
//! 3. pointwise h_eval = (a·b − c) · Z(coset)⁻¹ — Z is constant on the
//!    coset: Z(g·ωⁱ) = gⁿ − 1;
//! 4. coset-iNTT → h coefficients (1 transform).
//!
//! Seven transforms of size n — matching the NTT share the paper's Table I
//! attributes to a Groth16 prover. All seven run through **one cached
//! [`NttPlan`](crate::ntt::NttPlan)** (built lazily inside the domain and
//! reused transform over transform), optionally across a caller-chosen
//! thread budget ([`compute_h_with`]); [`NttPhases`] reports how the NTT
//! wall time splits across the pipeline's stages.

use crate::ff::lanes::{FpLanes, LANES};
use crate::ff::{Field, FieldParams, Fp};
use crate::ntt::domain::Domain;
use crate::util::Stopwatch;

/// The quotient polynomial h and the domain it was computed over.
pub struct QapWitness<P: FieldParams<N>, const N: usize> {
    /// The n-point evaluation domain used.
    pub domain: Domain<P, N>,
    /// Coefficients of h(x), degree < n − 1.
    pub h_coeffs: Vec<Fp<P, N>>,
}

/// Wall-clock split of the QAP reduction's NTT phase (the
/// `ProfileBreakdown::ntt_phases` field) — one entry per stage of the
/// h-polynomial pipeline.
#[derive(Clone, Copy, Debug, Default)]
pub struct NttPhases {
    /// The 3 inverse transforms (constraint evaluations → coefficients).
    pub intt_s: f64,
    /// The 3 forward coset transforms (coefficients → coset evaluations).
    pub coset_ntt_s: f64,
    /// The pointwise (a·b − c)·Z⁻¹ pass over the coset evaluations.
    pub pointwise_s: f64,
    /// The final coset inverse transform (→ h coefficients).
    pub coset_intt_s: f64,
}

impl NttPhases {
    /// Total across the four phases.
    pub fn total_s(&self) -> f64 {
        self.intt_s + self.coset_ntt_s + self.pointwise_s + self.coset_intt_s
    }
}

/// Compute h(x) from constraint evaluations (padded with zeros to the next
/// power of two ≥ len + 1) — single-threaded convenience wrapper over
/// [`compute_h_with`].
pub fn compute_h<P: FieldParams<N>, const N: usize>(
    a_evals: &[Fp<P, N>],
    b_evals: &[Fp<P, N>],
    c_evals: &[Fp<P, N>],
) -> Option<QapWitness<P, N>> {
    compute_h_with(a_evals, b_evals, c_evals, 1).map(|(qap, _)| qap)
}

/// Compute h(x) with all seven domain transforms running through the
/// domain's cached [`NttPlan`](crate::ntt::NttPlan) over `threads` OS
/// threads. `threads == 1` runs inline (the Table I measurement default);
/// the h coefficients are bit-identical for every thread count.
pub fn compute_h_with<P: FieldParams<N>, const N: usize>(
    a_evals: &[Fp<P, N>],
    b_evals: &[Fp<P, N>],
    c_evals: &[Fp<P, N>],
    threads: usize,
) -> Option<(QapWitness<P, N>, NttPhases)> {
    assert_eq!(a_evals.len(), b_evals.len());
    assert_eq!(a_evals.len(), c_evals.len());
    let threads = threads.max(1);
    let n = (a_evals.len().max(2)).next_power_of_two();
    let domain = Domain::<P, N>::new(n)?;
    // one plan serves every transform below (twiddle tables built once)
    let plan = domain.plan();
    let mut phases = NttPhases::default();

    let mut a = a_evals.to_vec();
    let mut b = b_evals.to_vec();
    let mut c = c_evals.to_vec();
    for v in [&mut a, &mut b, &mut c] {
        v.resize(n, Fp::<P, N>::zero());
    }

    // evaluations → coefficients (3 iNTTs)
    let sw = Stopwatch::start();
    plan.intt(&mut a, threads);
    plan.intt(&mut b, threads);
    plan.intt(&mut c, threads);
    phases.intt_s = sw.secs();

    // coefficients → coset evaluations (3 coset NTTs)
    let sw = Stopwatch::start();
    plan.coset_ntt(&mut a, threads);
    plan.coset_ntt(&mut b, threads);
    plan.coset_ntt(&mut c, threads);
    phases.coset_ntt_s = sw.secs();

    // Z(g·ωⁱ) = gⁿ − 1, constant over the coset
    let sw = Stopwatch::start();
    let z_coset = domain
        .coset_gen
        .pow_u64(n as u64)
        .sub(&Fp::<P, N>::one());
    let z_inv = z_coset.inv()?;

    // pointwise (a·b − c)·Z⁻¹, four lanes per step (n is a power of two
    // ≥ 2, so only n = 2 takes the scalar tail)
    let mut h = vec![Fp::<P, N>::zero(); n];
    let zs = FpLanes::splat(&z_inv);
    let mut i = 0;
    while i + LANES <= n {
        let av = FpLanes::load(&a[i..]);
        let bv = FpLanes::load(&b[i..]);
        let cv = FpLanes::load(&c[i..]);
        av.mul4(&bv).sub4(&cv).mul4(&zs).store(&mut h[i..]);
        i += LANES;
    }
    for j in i..n {
        h[j] = a[j].mul(&b[j]).sub(&c[j]).mul(&z_inv);
    }
    phases.pointwise_s = sw.secs();

    // coset evaluations → h coefficients (1 coset iNTT)
    let sw = Stopwatch::start();
    plan.coset_intt(&mut h, threads);
    phases.coset_intt_s = sw.secs();
    Some((QapWitness { domain, h_coeffs: h }, phases))
}

/// Verify the QAP identity A(x)·B(x) − C(x) = h(x)·Z(x) at a random point
/// outside the domain — a Schwartz–Zippel self-check of the whole
/// transformation (and, transitively, of the NTT stack).
pub fn check_identity<P: FieldParams<N>, const N: usize>(
    a_evals: &[Fp<P, N>],
    b_evals: &[Fp<P, N>],
    c_evals: &[Fp<P, N>],
    qap: &QapWitness<P, N>,
    rng: &mut crate::util::rng::Rng,
) -> bool {
    let n = qap.domain.n;
    let x = Fp::<P, N>::random(rng);
    if qap.domain.vanishing_at(&x).is_zero() {
        return true; // astronomically unlikely; x in domain trivially holds
    }
    // interpolate A,B,C coefficient forms again for evaluation
    let mut a = a_evals.to_vec();
    let mut b = b_evals.to_vec();
    let mut c = c_evals.to_vec();
    for v in [&mut a, &mut b, &mut c] {
        v.resize(n, Fp::<P, N>::zero());
    }
    // the witness's domain already holds the cached plan — reuse it
    let plan = qap.domain.plan();
    plan.intt(&mut a, 1);
    plan.intt(&mut b, 1);
    plan.intt(&mut c, 1);

    let eval = |coeffs: &[Fp<P, N>]| {
        let mut acc = Fp::<P, N>::zero();
        for co in coeffs.iter().rev() {
            acc = acc.mul(&x).add(co);
        }
        acc
    };
    let lhs = eval(&a).mul(&eval(&b)).sub(&eval(&c));
    let rhs = eval(&qap.h_coeffs).mul(&qap.domain.vanishing_at(&x));
    lhs == rhs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::params::Bn254FrParams;
    use crate::snark::circuits;
    use crate::util::rng::Rng;

    #[test]
    fn qap_identity_holds_for_satisfied_circuit() {
        let cs = circuits::mul_chain::<Bn254FrParams, 4>(50, 11);
        assert!(cs.is_satisfied());
        let (a, b, c) = cs.constraint_evals();
        let qap = compute_h(&a, &b, &c).expect("domain fits");
        let mut rng = Rng::new(42);
        for _ in 0..3 {
            assert!(check_identity(&a, &b, &c, &qap, &mut rng));
        }
    }

    #[test]
    fn qap_identity_fails_for_unsatisfied_circuit() {
        let cs = circuits::mul_chain::<Bn254FrParams, 4>(50, 12);
        let (a, b, mut c) = cs.constraint_evals();
        // corrupt one constraint's C evaluation: AB−C no longer divisible
        c[7] = c[7].add(&crate::ff::FrBn254::one());
        let qap = compute_h(&a, &b, &c).expect("computes regardless");
        let mut rng = Rng::new(43);
        assert!(!check_identity(&a, &b, &c, &qap, &mut rng));
    }

    #[test]
    fn h_degree_bound() {
        let cs = circuits::square_chain::<Bn254FrParams, 4>(30, 13);
        let (a, b, c) = cs.constraint_evals();
        let qap = compute_h(&a, &b, &c).unwrap();
        // h degree ≤ n−2 ⇒ top coefficient zero
        assert!(qap.h_coeffs.last().unwrap().is_zero());
    }

    #[test]
    fn h_bit_identical_across_thread_counts_with_phases() {
        let cs = circuits::mul_chain::<Bn254FrParams, 4>(120, 15);
        let (a, b, c) = cs.constraint_evals();
        let (q1, p1) = compute_h_with(&a, &b, &c, 1).expect("domain fits");
        assert!(p1.total_s() > 0.0, "{p1:?}");
        assert!(p1.intt_s > 0.0 && p1.coset_ntt_s > 0.0 && p1.coset_intt_s > 0.0, "{p1:?}");
        for threads in [2usize, 8, 32] {
            let (qt, _) = compute_h_with(&a, &b, &c, threads).unwrap();
            assert_eq!(qt.h_coeffs, q1.h_coeffs, "threads={threads}");
        }
        let mut rng = Rng::new(16);
        assert!(check_identity(&a, &b, &c, &q1, &mut rng));
    }

    #[test]
    fn pads_to_power_of_two() {
        let cs = circuits::mul_chain::<Bn254FrParams, 4>(33, 14);
        let (a, b, c) = cs.constraint_evals();
        let qap = compute_h(&a, &b, &c).unwrap();
        assert_eq!(qap.domain.n, 64);
    }
}
