//! Rank-1 constraint systems: ⟨A_i, w⟩ · ⟨B_i, w⟩ = ⟨C_i, w⟩ for each
//! constraint i, over the scalar field Fr.
//!
//! Gadgets compose through [`LinearCombination`], a normalized symbolic
//! term list (sorted by wire, zero coefficients dropped): circuit builders
//! keep whole linear layers symbolic and only materialize witness wires at
//! multiplications, so constraint counts track multiplicative depth rather
//! than formula size.

use crate::ff::{Field, FieldParams, Fp};

/// A sparse linear combination over witness indices.
pub type Lc<F> = Vec<(usize, F)>;

/// A symbolic linear combination `Σ coeff_j · w_{idx_j}`, normalized:
/// terms are sorted by wire index, duplicate wires merged, zero
/// coefficients dropped. Wire 0 is the constant 1, so field constants are
/// ordinary terms on wire 0.
#[derive(Clone, Debug, Default)]
pub struct LinearCombination<F: Field> {
    terms: Vec<(usize, F)>,
}

impl<F: Field> LinearCombination<F> {
    /// The empty combination (evaluates to 0).
    pub fn zero() -> Self {
        LinearCombination { terms: Vec::new() }
    }

    /// A single wire with coefficient 1.
    pub fn var(index: usize) -> Self {
        LinearCombination { terms: vec![(index, F::one())] }
    }

    /// A field constant (a term on the constant wire 0).
    pub fn constant(value: F) -> Self {
        Self::term(0, value)
    }

    /// A single wire with an arbitrary coefficient.
    pub fn term(index: usize, coeff: F) -> Self {
        if coeff.is_zero() {
            return Self::zero();
        }
        LinearCombination { terms: vec![(index, coeff)] }
    }

    /// `self + other`, merging duplicate wires.
    pub fn plus(&self, other: &Self) -> Self {
        self.combine(other, false)
    }

    /// `self − other`, merging duplicate wires.
    pub fn minus(&self, other: &Self) -> Self {
        self.combine(other, true)
    }

    /// `k · self`.
    pub fn scaled(&self, k: &F) -> Self {
        if k.is_zero() {
            return Self::zero();
        }
        LinearCombination {
            terms: self.terms.iter().map(|(i, c)| (*i, c.mul(k))).collect(),
        }
    }

    /// The normalized `(wire, coefficient)` terms.
    pub fn terms(&self) -> &[(usize, F)] {
        &self.terms
    }

    /// Number of terms.
    pub fn len(&self) -> usize {
        self.terms.len()
    }

    /// Whether the combination is identically zero.
    pub fn is_empty(&self) -> bool {
        self.terms.is_empty()
    }

    /// Consume into the sparse [`Lc`] row format the matrices store.
    pub fn into_lc(self) -> Lc<F> {
        self.terms
    }

    // Sorted two-pointer merge; `negate` subtracts `other`.
    fn combine(&self, other: &Self, negate: bool) -> Self {
        let (a, b) = (&self.terms, &other.terms);
        let mut out = Vec::with_capacity(a.len() + b.len());
        let (mut i, mut j) = (0, 0);
        while i < a.len() || j < b.len() {
            let take_a = match (a.get(i), b.get(j)) {
                (Some(x), Some(y)) => x.0 <= y.0,
                (Some(_), None) => true,
                _ => false,
            };
            if take_a && j < b.len() && a[i].0 == b[j].0 {
                let rhs = if negate { b[j].1.neg() } else { b[j].1 };
                let c = a[i].1.add(&rhs);
                if !c.is_zero() {
                    out.push((a[i].0, c));
                }
                i += 1;
                j += 1;
            } else if take_a {
                out.push(a[i]);
                i += 1;
            } else {
                let c = if negate { b[j].1.neg() } else { b[j].1 };
                out.push((b[j].0, c));
                j += 1;
            }
        }
        LinearCombination { terms: out }
    }
}

/// An R1CS instance together with a satisfying witness.
///
/// Witness layout: `w[0] = 1` (the constant), then public inputs, then
/// private assignments.
#[derive(Clone, Debug)]
pub struct ConstraintSystem<P: FieldParams<N>, const N: usize> {
    /// Per-constraint A-side linear combinations.
    pub a: Vec<Lc<Fp<P, N>>>,
    /// Per-constraint B-side linear combinations.
    pub b: Vec<Lc<Fp<P, N>>>,
    /// Per-constraint C-side linear combinations.
    pub c: Vec<Lc<Fp<P, N>>>,
    /// The satisfying assignment (index 0 is the constant 1).
    pub witness: Vec<Fp<P, N>>,
    /// Leading witness entries (after the constant) that are public.
    pub num_public: usize,
}

impl<P: FieldParams<N>, const N: usize> ConstraintSystem<P, N> {
    /// Empty system with the constant-1 witness slot.
    pub fn new() -> Self {
        ConstraintSystem {
            a: Vec::new(),
            b: Vec::new(),
            c: Vec::new(),
            witness: vec![Fp::<P, N>::one()],
            num_public: 0,
        }
    }

    /// Number of constraints.
    pub fn num_constraints(&self) -> usize {
        self.a.len()
    }

    /// Number of witness variables (constant included).
    pub fn num_variables(&self) -> usize {
        self.witness.len()
    }

    /// Add a variable with an assignment; returns its index.
    pub fn alloc(&mut self, value: Fp<P, N>) -> usize {
        self.witness.push(value);
        self.witness.len() - 1
    }

    /// Add a *public-input* variable. The witness layout pins public
    /// inputs to the leading slots right after the constant
    /// (`w[1..=num_public]` — the slice the prover's L-query skips and
    /// the verifier's IC commitment covers), so every public allocation
    /// must happen before the first private one. Panics otherwise.
    pub fn alloc_public(&mut self, value: Fp<P, N>) -> usize {
        assert_eq!(
            self.witness.len(),
            1 + self.num_public,
            "alloc_public after a private alloc would break the \
             [1, publics.., privates..] witness layout"
        );
        self.num_public += 1;
        self.alloc(value)
    }

    /// Add a constraint from symbolic combinations: ⟨a,w⟩·⟨b,w⟩ = ⟨c,w⟩.
    pub fn enforce_lc(
        &mut self,
        a: &LinearCombination<Fp<P, N>>,
        b: &LinearCombination<Fp<P, N>>,
        c: &LinearCombination<Fp<P, N>>,
    ) {
        self.enforce(a.clone().into_lc(), b.clone().into_lc(), c.clone().into_lc());
    }

    /// Evaluate a symbolic combination against the witness.
    pub fn eval_comb(&self, lc: &LinearCombination<Fp<P, N>>) -> Fp<P, N> {
        let mut acc = Fp::<P, N>::zero();
        for (idx, coeff) in lc.terms() {
            acc = acc.add(&self.witness[*idx].mul(coeff));
        }
        acc
    }

    /// Materialize the product of two combinations: allocates a wire
    /// carrying `⟨a,w⟩·⟨b,w⟩`, enforces `a·b = wire`, returns the wire.
    /// The one place gadgets spend constraints — linear structure stays
    /// symbolic.
    pub fn mul_lc(
        &mut self,
        a: &LinearCombination<Fp<P, N>>,
        b: &LinearCombination<Fp<P, N>>,
    ) -> usize {
        let value = self.eval_comb(a).mul(&self.eval_comb(b));
        let out = self.alloc(value);
        self.enforce_lc(a, b, &LinearCombination::var(out));
        out
    }

    /// Enforce the linear constraint ⟨a,w⟩ = ⟨b,w⟩ (as `a · 1 = b`).
    pub fn enforce_eq(
        &mut self,
        a: &LinearCombination<Fp<P, N>>,
        b: &LinearCombination<Fp<P, N>>,
    ) {
        self.enforce_lc(a, &LinearCombination::constant(Fp::<P, N>::one()), b);
    }

    /// Enforce that a wire is boolean: `x · x = x` (roots 0 and 1 only).
    pub fn enforce_boolean(&mut self, index: usize) {
        let x = LinearCombination::var(index);
        self.enforce_lc(&x, &x, &x);
    }

    /// Add a constraint ⟨a,w⟩·⟨b,w⟩ = ⟨c,w⟩.
    pub fn enforce(&mut self, a: Lc<Fp<P, N>>, b: Lc<Fp<P, N>>, c: Lc<Fp<P, N>>) {
        self.a.push(a);
        self.b.push(b);
        self.c.push(c);
    }

    /// Evaluate a linear combination against the witness.
    pub fn eval_lc(&self, lc: &Lc<Fp<P, N>>) -> Fp<P, N> {
        let mut acc = Fp::<P, N>::zero();
        for (idx, coeff) in lc {
            acc = acc.add(&self.witness[*idx].mul(coeff));
        }
        acc
    }

    /// Check every constraint against the witness.
    pub fn is_satisfied(&self) -> bool {
        self.a
            .iter()
            .zip(&self.b)
            .zip(&self.c)
            .all(|((a, b), c)| self.eval_lc(a).mul(&self.eval_lc(b)) == self.eval_lc(c))
    }

    /// Per-constraint evaluations (⟨A_i,w⟩, ⟨B_i,w⟩, ⟨C_i,w⟩) — the QAP
    /// prover's starting vectors.
    pub fn constraint_evals(&self) -> (Vec<Fp<P, N>>, Vec<Fp<P, N>>, Vec<Fp<P, N>>) {
        let n = self.num_constraints();
        let mut av = Vec::with_capacity(n);
        let mut bv = Vec::with_capacity(n);
        let mut cv = Vec::with_capacity(n);
        for i in 0..n {
            av.push(self.eval_lc(&self.a[i]));
            bv.push(self.eval_lc(&self.b[i]));
            cv.push(self.eval_lc(&self.c[i]));
        }
        (av, bv, cv)
    }
}

impl<P: FieldParams<N>, const N: usize> Default for ConstraintSystem<P, N> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::params::Bn254FrParams;
    type Fr = crate::ff::FrBn254;
    type Cs = ConstraintSystem<Bn254FrParams, 4>;

    fn mul_constraint(cs: &mut Cs, x: usize, y: usize) -> usize {
        let z = cs.alloc(cs.witness[x].mul(&cs.witness[y]));
        cs.enforce(vec![(x, Fr::one())], vec![(y, Fr::one())], vec![(z, Fr::one())]);
        z
    }

    #[test]
    fn simple_multiplication_satisfied() {
        let mut cs = Cs::new();
        let x = cs.alloc(Fr::from_u64(3));
        let y = cs.alloc(Fr::from_u64(5));
        let z = mul_constraint(&mut cs, x, y);
        assert_eq!(cs.witness[z], Fr::from_u64(15));
        assert!(cs.is_satisfied());
    }

    #[test]
    fn wrong_witness_fails() {
        let mut cs = Cs::new();
        let x = cs.alloc(Fr::from_u64(3));
        let y = cs.alloc(Fr::from_u64(5));
        let z = cs.alloc(Fr::from_u64(16)); // wrong product
        cs.enforce(vec![(x, Fr::one())], vec![(y, Fr::one())], vec![(z, Fr::one())]);
        assert!(!cs.is_satisfied());
    }

    #[test]
    fn linear_combinations_with_constants() {
        // (2x + 1) * y = z with x=4, y=3 → z=27
        let mut cs = Cs::new();
        let x = cs.alloc(Fr::from_u64(4));
        let y = cs.alloc(Fr::from_u64(3));
        let z = cs.alloc(Fr::from_u64(27));
        cs.enforce(
            vec![(x, Fr::from_u64(2)), (0, Fr::one())],
            vec![(y, Fr::one())],
            vec![(z, Fr::one())],
        );
        assert!(cs.is_satisfied());
    }

    #[test]
    fn constraint_evals_match() {
        let mut cs = Cs::new();
        let x = cs.alloc(Fr::from_u64(7));
        mul_constraint(&mut cs, x, x);
        let (a, b, c) = cs.constraint_evals();
        assert_eq!(a[0], Fr::from_u64(7));
        assert_eq!(b[0], Fr::from_u64(7));
        assert_eq!(c[0], Fr::from_u64(49));
    }

    type L = LinearCombination<Fr>;

    #[test]
    fn lincomb_merges_sorts_and_drops_zeros() {
        let lc = L::term(3, Fr::from_u64(2))
            .plus(&L::term(1, Fr::from_u64(5)))
            .plus(&L::term(3, Fr::from_u64(4)));
        assert_eq!(lc.terms(), &[(1, Fr::from_u64(5)), (3, Fr::from_u64(6))]);
        let cancelled = lc.minus(&lc);
        assert!(cancelled.is_empty());
        assert_eq!(cancelled.len(), 0);
        let scaled = lc.scaled(&Fr::from_u64(3));
        assert_eq!(scaled.terms()[1], (3, Fr::from_u64(18)));
        assert!(lc.scaled(&Fr::zero()).is_empty());
        assert!(L::term(9, Fr::zero()).is_empty());
    }

    #[test]
    fn lincomb_eval_and_mul_lc() {
        // (2x + 1)(y) = z via the builder, same statement as the
        // hand-rolled `linear_combinations_with_constants` above
        let mut cs = Cs::new();
        let x = cs.alloc(Fr::from_u64(4));
        let y = cs.alloc(Fr::from_u64(3));
        let lhs = L::var(x).scaled(&Fr::from_u64(2)).plus(&L::constant(Fr::one()));
        assert_eq!(cs.eval_comb(&lhs), Fr::from_u64(9));
        let z = cs.mul_lc(&lhs, &L::var(y));
        assert_eq!(cs.witness[z], Fr::from_u64(27));
        assert!(cs.is_satisfied());
    }

    #[test]
    fn enforce_eq_and_boolean() {
        let mut cs = Cs::new();
        let b = cs.alloc(Fr::one());
        cs.enforce_boolean(b);
        let t = cs.alloc(Fr::from_u64(11));
        // t = 10·b + 1
        cs.enforce_eq(
            &L::var(t),
            &L::term(b, Fr::from_u64(10)).plus(&L::constant(Fr::one())),
        );
        assert!(cs.is_satisfied());
        cs.witness[b] = Fr::from_u64(2); // non-boolean
        assert!(!cs.is_satisfied());
    }

    #[test]
    fn alloc_public_pins_leading_layout() {
        // regression for num_public semantics: publics occupy
        // w[1..=num_public], exactly the slots the prover's L-query
        // slicing (l_start = 1 + num_public) assumes
        let mut cs = Cs::new();
        let p0 = cs.alloc_public(Fr::from_u64(10));
        let p1 = cs.alloc_public(Fr::from_u64(20));
        assert_eq!((p0, p1), (1, 2));
        assert_eq!(cs.num_public, 2);
        let x = cs.alloc(Fr::from_u64(200));
        assert_eq!(x, 3);
        cs.enforce_eq(&L::var(x), &L::var(p0).scaled(&Fr::from_u64(20)));
        assert!(cs.is_satisfied());
        assert_eq!(&cs.witness[1..=cs.num_public], &[Fr::from_u64(10), Fr::from_u64(20)]);
    }

    #[test]
    #[should_panic(expected = "alloc_public after a private alloc")]
    fn alloc_public_after_private_panics() {
        let mut cs = Cs::new();
        cs.alloc(Fr::from_u64(1));
        cs.alloc_public(Fr::from_u64(2));
    }
}
