//! Rank-1 constraint systems: ⟨A_i, w⟩ · ⟨B_i, w⟩ = ⟨C_i, w⟩ for each
//! constraint i, over the scalar field Fr.

use crate::ff::{Field, FieldParams, Fp};

/// A sparse linear combination over witness indices.
pub type Lc<F> = Vec<(usize, F)>;

/// An R1CS instance together with a satisfying witness.
///
/// Witness layout: `w[0] = 1` (the constant), then public inputs, then
/// private assignments.
#[derive(Clone, Debug)]
pub struct ConstraintSystem<P: FieldParams<N>, const N: usize> {
    /// Per-constraint A-side linear combinations.
    pub a: Vec<Lc<Fp<P, N>>>,
    /// Per-constraint B-side linear combinations.
    pub b: Vec<Lc<Fp<P, N>>>,
    /// Per-constraint C-side linear combinations.
    pub c: Vec<Lc<Fp<P, N>>>,
    /// The satisfying assignment (index 0 is the constant 1).
    pub witness: Vec<Fp<P, N>>,
    /// Leading witness entries (after the constant) that are public.
    pub num_public: usize,
}

impl<P: FieldParams<N>, const N: usize> ConstraintSystem<P, N> {
    /// Empty system with the constant-1 witness slot.
    pub fn new() -> Self {
        ConstraintSystem {
            a: Vec::new(),
            b: Vec::new(),
            c: Vec::new(),
            witness: vec![Fp::<P, N>::one()],
            num_public: 0,
        }
    }

    /// Number of constraints.
    pub fn num_constraints(&self) -> usize {
        self.a.len()
    }

    /// Number of witness variables (constant included).
    pub fn num_variables(&self) -> usize {
        self.witness.len()
    }

    /// Add a variable with an assignment; returns its index.
    pub fn alloc(&mut self, value: Fp<P, N>) -> usize {
        self.witness.push(value);
        self.witness.len() - 1
    }

    /// Add a constraint ⟨a,w⟩·⟨b,w⟩ = ⟨c,w⟩.
    pub fn enforce(&mut self, a: Lc<Fp<P, N>>, b: Lc<Fp<P, N>>, c: Lc<Fp<P, N>>) {
        self.a.push(a);
        self.b.push(b);
        self.c.push(c);
    }

    /// Evaluate a linear combination against the witness.
    pub fn eval_lc(&self, lc: &Lc<Fp<P, N>>) -> Fp<P, N> {
        let mut acc = Fp::<P, N>::zero();
        for (idx, coeff) in lc {
            acc = acc.add(&self.witness[*idx].mul(coeff));
        }
        acc
    }

    /// Check every constraint against the witness.
    pub fn is_satisfied(&self) -> bool {
        self.a
            .iter()
            .zip(&self.b)
            .zip(&self.c)
            .all(|((a, b), c)| self.eval_lc(a).mul(&self.eval_lc(b)) == self.eval_lc(c))
    }

    /// Per-constraint evaluations (⟨A_i,w⟩, ⟨B_i,w⟩, ⟨C_i,w⟩) — the QAP
    /// prover's starting vectors.
    pub fn constraint_evals(&self) -> (Vec<Fp<P, N>>, Vec<Fp<P, N>>, Vec<Fp<P, N>>) {
        let n = self.num_constraints();
        let mut av = Vec::with_capacity(n);
        let mut bv = Vec::with_capacity(n);
        let mut cv = Vec::with_capacity(n);
        for i in 0..n {
            av.push(self.eval_lc(&self.a[i]));
            bv.push(self.eval_lc(&self.b[i]));
            cv.push(self.eval_lc(&self.c[i]));
        }
        (av, bv, cv)
    }
}

impl<P: FieldParams<N>, const N: usize> Default for ConstraintSystem<P, N> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::params::Bn254FrParams;
    type Fr = crate::ff::FrBn254;
    type Cs = ConstraintSystem<Bn254FrParams, 4>;

    fn mul_constraint(cs: &mut Cs, x: usize, y: usize) -> usize {
        let z = cs.alloc(cs.witness[x].mul(&cs.witness[y]));
        cs.enforce(vec![(x, Fr::one())], vec![(y, Fr::one())], vec![(z, Fr::one())]);
        z
    }

    #[test]
    fn simple_multiplication_satisfied() {
        let mut cs = Cs::new();
        let x = cs.alloc(Fr::from_u64(3));
        let y = cs.alloc(Fr::from_u64(5));
        let z = mul_constraint(&mut cs, x, y);
        assert_eq!(cs.witness[z], Fr::from_u64(15));
        assert!(cs.is_satisfied());
    }

    #[test]
    fn wrong_witness_fails() {
        let mut cs = Cs::new();
        let x = cs.alloc(Fr::from_u64(3));
        let y = cs.alloc(Fr::from_u64(5));
        let z = cs.alloc(Fr::from_u64(16)); // wrong product
        cs.enforce(vec![(x, Fr::one())], vec![(y, Fr::one())], vec![(z, Fr::one())]);
        assert!(!cs.is_satisfied());
    }

    #[test]
    fn linear_combinations_with_constants() {
        // (2x + 1) * y = z with x=4, y=3 → z=27
        let mut cs = Cs::new();
        let x = cs.alloc(Fr::from_u64(4));
        let y = cs.alloc(Fr::from_u64(3));
        let z = cs.alloc(Fr::from_u64(27));
        cs.enforce(
            vec![(x, Fr::from_u64(2)), (0, Fr::one())],
            vec![(y, Fr::one())],
            vec![(z, Fr::one())],
        );
        assert!(cs.is_satisfied());
    }

    #[test]
    fn constraint_evals_match() {
        let mut cs = Cs::new();
        let x = cs.alloc(Fr::from_u64(7));
        mul_constraint(&mut cs, x, x);
        let (a, b, c) = cs.constraint_evals();
        assert_eq!(a[0], Fr::from_u64(7));
        assert_eq!(b[0], Fr::from_u64(7));
        assert_eq!(c[0], Fr::from_u64(49));
    }
}
