//! Groth16-shaped prover pipeline — the workload behind Table I.
//!
//! The paper motivates MSM acceleration by profiling the libsnark prover
//! (§II-D): MSM-𝔾₁ + MSM-𝔾₂ consume ~88–92% of prover time, NTT most of
//! the rest. To *measure* (not assume) that breakdown, this module
//! implements the full prover compute pipeline:
//!
//! * [`r1cs`] — rank-1 constraint systems with a symbolic
//!   [`LinearCombination`] builder, plus the circuit library
//!   ([`circuits`]): two synthetic chains and four real workloads
//!   (Poseidon2 hash chains, Merkle membership, range decomposition,
//!   rollup batch transfers), each selectable as a CLI [`Scenario`];
//! * [`qap`] — the R1CS→QAP reduction: witness evaluation over the NTT
//!   domain, coset division by the vanishing polynomial, h(x) extraction;
//! * [`setup`] — a *structure-preserving synthetic CRS* (sizes and group
//!   placement match Groth16; the points are deterministic generator
//!   multiples rather than toxic-waste powers — the proof is not
//!   cryptographically sound, but every MSM/NTT the real prover executes
//!   is executed here with the right sizes, fields and groups);
//! * [`prover`] — the instrumented prover producing the Table I split;
//! * [`stream`] — the bounded-memory streaming prover: generator- or
//!   disk-backed SRS chunk sources + [`stream::prove_streaming`] under an
//!   enforced [`crate::util::mem::MemoryBudget`], bit-identical to the
//!   resident path;
//! * [`verify`] — the transcript-consistency verifier: curve-membership
//!   checks on every proof element plus recomputation of the
//!   public-input commitment π over the verifying key's IC basis.
//!   Honest about its limits: the synthetic CRS has no τ structure, so
//!   this is consistency checking with real verifier kernels, not
//!   cryptographic soundness.

pub mod r1cs;
pub mod circuits;
pub mod qap;
pub mod setup;
pub mod prover;
pub mod stream;
pub mod verify;

pub use circuits::{Scenario, ScenarioInstance};
pub use prover::{ProfileBreakdown, Proof, Prover, ProverConfig};
pub use qap::NttPhases;
pub use r1cs::{ConstraintSystem, LinearCombination};
pub use stream::{prove_streaming, StreamReport, StreamingSrs, WitnessStream};
pub use verify::{verify, VerifyError, VerifyingKey};
