//! Circuit library — the workloads the prover is profiled against.
//!
//! Two synthetic chains ([`synthetic`]) provide arbitrary-size R1CS
//! stress shapes, and four real workloads mirror what production SNARK
//! deployments actually prove:
//!
//! * [`poseidon2`] — an algebraic permutation (x⁵ S-box, full/partial
//!   rounds) and hash chains built from it,
//! * [`merkle`] — membership paths under the Poseidon2 compression
//!   function,
//! * [`range`] — k-bit decomposition range checks,
//! * [`rollup`] — batch balance transfers composing Merkle updates,
//!   range checks and conservation constraints.
//!
//! Every workload comes as a triple: an out-of-circuit reference, a
//! constraint-system builder (gadget), and a witness generator; the
//! property tests pin gadget == reference. [`Scenario`] names them for
//! the CLI (`prove --scenario`, `tables --id scenarios`) and builds a
//! sized instance of each.

pub mod merkle;
pub mod poseidon2;
pub mod range;
pub mod rollup;
pub mod synthetic;

pub use synthetic::{mul_chain, square_chain};

use crate::ff::{FieldParams, Fp};
use crate::snark::r1cs::ConstraintSystem;

/// A named prover workload, selectable from the CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scenario {
    /// Synthetic multiplication chain (`x_{i+2} = x_{i+1}·x_i`).
    MulChain,
    /// Synthetic square-accumulate chain (`x ← x² + c`).
    SquareChain,
    /// Poseidon2 hash chain (repeated full permutations).
    Poseidon2,
    /// Merkle membership paths under Poseidon2 compression.
    Merkle,
    /// k-bit range decompositions.
    Range,
    /// Rollup-style batch transfers (Merkle updates + ranges).
    Rollup,
}

/// A built scenario: the constraint system, its claimed public inputs
/// (`witness[1..=num_public]`), and a human-readable shape string.
pub struct ScenarioInstance<P: FieldParams<N>, const N: usize> {
    /// The satisfied constraint system.
    pub cs: ConstraintSystem<P, N>,
    /// Public inputs in wire order.
    pub public_inputs: Vec<Fp<P, N>>,
    /// Shape summary, e.g. `depth=4 paths=8`.
    pub shape: String,
}

impl Scenario {
    /// Every scenario, CLI order.
    pub const ALL: [Scenario; 6] = [
        Scenario::MulChain,
        Scenario::SquareChain,
        Scenario::Poseidon2,
        Scenario::Merkle,
        Scenario::Range,
        Scenario::Rollup,
    ];

    /// The four real workloads (everything but the synthetic chains).
    pub const WORKLOADS: [Scenario; 4] =
        [Scenario::Poseidon2, Scenario::Merkle, Scenario::Range, Scenario::Rollup];

    /// CLI name.
    pub fn name(self) -> &'static str {
        match self {
            Scenario::MulChain => "mul-chain",
            Scenario::SquareChain => "square-chain",
            Scenario::Poseidon2 => "poseidon2",
            Scenario::Merkle => "merkle",
            Scenario::Range => "range",
            Scenario::Rollup => "rollup",
        }
    }

    /// Inverse of [`Scenario::name`].
    pub fn parse(s: &str) -> Option<Scenario> {
        Scenario::ALL.into_iter().find(|sc| sc.name() == s)
    }

    /// Build an instance sized to roughly `size` constraints. Each
    /// scenario translates the budget into its own shape parameters
    /// (chain length, tree depth × path count, value count, transfer
    /// count) so profiles at equal `size` are comparable across
    /// workloads.
    pub fn build<P: FieldParams<N>, const N: usize>(
        self,
        size: usize,
        seed: u64,
    ) -> ScenarioInstance<P, N> {
        let size = size.max(1);
        match self {
            Scenario::MulChain => {
                let cs = mul_chain::<P, N>(size, seed);
                finish(cs, format!("n={size}"))
            }
            Scenario::SquareChain => {
                let cs = square_chain::<P, N>(size, seed);
                finish(cs, format!("n={size}"))
            }
            Scenario::Poseidon2 => {
                // ≈241 constraints per permutation (240 + final binding)
                let n_perms = (size / 241).max(1);
                let (cs, _) = poseidon2::hash_chain_circuit::<P, N>(n_perms, seed);
                finish(cs, format!("perms={n_perms}"))
            }
            Scenario::Merkle => {
                // ≈243 constraints per tree level
                let depth = (size / 243).clamp(1, 8);
                let n_paths = (size / (depth * 243)).max(1);
                let (cs, _) = merkle::membership_circuit::<P, N>(depth, n_paths, seed);
                finish(cs, format!("depth={depth} paths={n_paths}"))
            }
            Scenario::Range => {
                let k = 32;
                let n_values = (size / (k + 1)).max(1);
                let (cs, _) = range::range_circuit::<P, N>(k, n_values, seed);
                finish(cs, format!("k={k} values={n_values}"))
            }
            Scenario::Rollup => {
                let depth = (size / 1000).clamp(1, 4);
                let amount_bits = 40;
                // 4 root recomputations + 3 range checks + glue
                let per_transfer = 4 * 242 * depth + 3 * (amount_bits + 1) + 5;
                let n_transfers = (size / per_transfer).max(1);
                let (cs, _) = rollup::rollup_circuit::<P, N>(depth, n_transfers, amount_bits, seed);
                finish(cs, format!("depth={depth} transfers={n_transfers} k={amount_bits}"))
            }
        }
    }
}

fn finish<P: FieldParams<N>, const N: usize>(
    cs: ConstraintSystem<P, N>,
    shape: String,
) -> ScenarioInstance<P, N> {
    let public_inputs = cs.witness[1..=cs.num_public].to_vec();
    ScenarioInstance { cs, public_inputs, shape }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::params::{Bls12381FrParams, Bn254FrParams};

    #[test]
    fn names_parse_back() {
        for sc in Scenario::ALL {
            assert_eq!(Scenario::parse(sc.name()), Some(sc));
        }
        assert_eq!(Scenario::parse("no-such"), None);
    }

    #[test]
    fn every_scenario_builds_satisfied_instances() {
        for sc in Scenario::ALL {
            let inst = sc.build::<Bn254FrParams, 4>(300, 11);
            assert!(inst.cs.is_satisfied(), "{} unsatisfied", sc.name());
            assert_eq!(inst.public_inputs.len(), inst.cs.num_public);
            assert!(!inst.shape.is_empty());
            let inst = sc.build::<Bls12381FrParams, 4>(300, 11);
            assert!(inst.cs.is_satisfied(), "{} unsatisfied on bls", sc.name());
        }
    }

    #[test]
    fn workloads_are_the_non_synthetic_subset() {
        for sc in Scenario::WORKLOADS {
            assert!(sc != Scenario::MulChain && sc != Scenario::SquareChain);
            assert!(Scenario::ALL.contains(&sc));
        }
    }
}
