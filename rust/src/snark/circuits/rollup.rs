//! Rollup-style batch-transfer circuit.
//!
//! Composes the other gadgets into the shape a rollup prover runs: a
//! Merkle tree of account balances, a batch of transfers, and one proof
//! that replaying the batch takes the tree from `old_root` to
//! `new_root` (the two public inputs). Each transfer proves:
//!
//! 1. sender membership under the running root, sender debit
//!    (`new = old − amount`), and the updated running root along the
//!    *same* path wires (so debit and credit provably hit the same slot),
//! 2. the symmetric receiver credit,
//! 3. balance conservation: `old_s + old_r = new_s + new_r` over the
//!    four independently allocated balance wires,
//! 4. range checks on the amount and both new balances (no negative
//!    balances, no wrap-around minting).

use super::merkle::{alloc_path, root_gadget, MerkleTree};
use super::poseidon2::Poseidon2;
use super::range::range_gadget;
use crate::ff::{Field, FieldParams, Fp};
use crate::snark::r1cs::{ConstraintSystem, LinearCombination};
use crate::util::rng::Rng;

/// One balance transfer between two leaf accounts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Transfer {
    /// Sender account index (leaf position).
    pub from: usize,
    /// Receiver account index (leaf position).
    pub to: usize,
    /// Amount moved, in base units.
    pub amount: u64,
}

/// Witness material for one transfer, recorded while simulating the
/// batch against the reference tree.
struct Step<P: FieldParams<N>, const N: usize> {
    transfer: Transfer,
    sender_old: Fp<P, N>,
    sender_new: Fp<P, N>,
    sender_sibs: Vec<Fp<P, N>>,
    receiver_old: Fp<P, N>,
    receiver_new: Fp<P, N>,
    receiver_sibs: Vec<Fp<P, N>>,
}

/// Build the batch-transfer circuit for `balances` (one per leaf,
/// power-of-two count) and `transfers`, range-checking amounts and new
/// balances to `amount_bits` bits. Returns the system and the public
/// inputs `[old_root, new_root]`.
///
/// Panics on overdraft, self-transfer, out-of-range account index, or
/// if the total supply does not fit in `amount_bits` bits (the clean
/// no-overflow invariant: every intermediate balance is then below
/// `2^amount_bits`, so the u64 witness arithmetic and the in-circuit
/// range checks agree).
pub fn batch_transfer_circuit<P: FieldParams<N>, const N: usize>(
    balances: &[u64],
    transfers: &[Transfer],
    amount_bits: usize,
) -> (ConstraintSystem<P, N>, Vec<Fp<P, N>>) {
    assert!(amount_bits >= 1 && amount_bits <= 63, "amount_bits out of range");
    assert!(balances.len().is_power_of_two() && balances.len() >= 2, "need 2^d >= 2 accounts");
    let supply: u64 = balances.iter().fold(0u64, |a, b| {
        a.checked_add(*b).expect("total supply overflows u64")
    });
    assert!(supply < 1u64 << amount_bits, "total supply must fit in amount_bits");

    // Pass 1: simulate the batch on the reference tree, recording per-
    // transfer membership paths *as seen at that point in the replay*.
    let hasher = Poseidon2::<P, N>::standard();
    let leaves: Vec<Fp<P, N>> = balances.iter().map(|b| Fp::from_u64(*b)).collect();
    let mut tree = MerkleTree::new(hasher.clone(), leaves);
    let mut bal = balances.to_vec();
    let old_root = tree.root();
    let mut steps = Vec::with_capacity(transfers.len());
    for t in transfers {
        assert!(t.from < bal.len() && t.to < bal.len(), "account index out of range");
        assert!(t.from != t.to, "self-transfer not supported");
        assert!(t.amount <= bal[t.from], "overdraft");
        let sender_old = tree.leaf(t.from);
        let sender_sibs = tree.path(t.from);
        bal[t.from] -= t.amount;
        let sender_new = Fp::from_u64(bal[t.from]);
        tree.update(t.from, sender_new);
        let receiver_old = tree.leaf(t.to);
        let receiver_sibs = tree.path(t.to);
        bal[t.to] += t.amount;
        let receiver_new = Fp::from_u64(bal[t.to]);
        tree.update(t.to, receiver_new);
        steps.push(Step {
            transfer: *t,
            sender_old,
            sender_new,
            sender_sibs,
            receiver_old,
            receiver_new,
            receiver_sibs,
        });
    }
    let new_root = tree.root();

    // Pass 2: synthesize. The running root starts at the public old
    // root and must land on the public new root.
    let mut cs = ConstraintSystem::<P, N>::new();
    let w_old = cs.alloc_public(old_root);
    let w_new = cs.alloc_public(new_root);
    let mut running = LinearCombination::var(w_old);
    for s in &steps {
        let amt = LinearCombination::var(cs.alloc(Fp::from_u64(s.transfer.amount)));

        // sender: membership, debit, re-root along the same path wires
        let so = LinearCombination::var(cs.alloc(s.sender_old));
        let path = alloc_path(&mut cs, s.transfer.from, &s.sender_sibs);
        let got = root_gadget(&hasher, &mut cs, &so, &path);
        cs.enforce_eq(&got, &running);
        let sn = LinearCombination::var(cs.alloc(s.sender_new));
        cs.enforce_eq(&sn, &so.minus(&amt));
        running = root_gadget(&hasher, &mut cs, &sn, &path);

        // receiver: membership under the debited root, credit, re-root
        let ro = LinearCombination::var(cs.alloc(s.receiver_old));
        let path = alloc_path(&mut cs, s.transfer.to, &s.receiver_sibs);
        let got = root_gadget(&hasher, &mut cs, &ro, &path);
        cs.enforce_eq(&got, &running);
        let rn = LinearCombination::var(cs.alloc(s.receiver_new));
        cs.enforce_eq(&rn, &ro.plus(&amt));
        running = root_gadget(&hasher, &mut cs, &rn, &path);

        // conservation over the four independent balance wires
        cs.enforce_eq(&so.plus(&ro), &sn.plus(&rn));

        // no negative balances, no wrap-around
        range_gadget(&mut cs, &amt, amount_bits);
        range_gadget(&mut cs, &sn, amount_bits);
        range_gadget(&mut cs, &rn, amount_bits);
    }
    cs.enforce_eq(&running, &LinearCombination::var(w_new));
    (cs, vec![old_root, new_root])
}

/// Domain-separation constant for the rollup scenario generator.
const ROLLUP_SEED: u64 = 0x84f0_66c1_2ad9_b735;

/// The rollup scenario circuit: a `2^depth`-account tree with random
/// balances and `n_transfers` random valid transfers.
pub fn rollup_circuit<P: FieldParams<N>, const N: usize>(
    depth: usize,
    n_transfers: usize,
    amount_bits: usize,
    seed: u64,
) -> (ConstraintSystem<P, N>, Vec<Fp<P, N>>) {
    assert!((1..=16).contains(&depth), "depth out of range");
    assert!(amount_bits >= depth + 2 && amount_bits <= 63, "amount_bits too small for depth");
    let n_transfers = n_transfers.max(1);
    let mut rng = Rng::new(seed ^ ROLLUP_SEED);
    let n_accounts = 1usize << depth;
    // per-account balances below 2^(amount_bits − depth − 1) keep the
    // total supply strictly below 2^amount_bits
    let mut balances: Vec<u64> =
        (0..n_accounts).map(|_| rng.below(1u64 << (amount_bits - depth - 1))).collect();
    let initial = balances.clone();
    let transfers: Vec<Transfer> = (0..n_transfers)
        .map(|_| {
            let from = rng.below(n_accounts as u64) as usize;
            let mut to = rng.below(n_accounts as u64) as usize;
            while to == from {
                to = rng.below(n_accounts as u64) as usize;
            }
            let amount = rng.below(balances[from] + 1);
            balances[from] -= amount;
            balances[to] += amount;
            Transfer { from, to, amount }
        })
        .collect();
    batch_transfer_circuit(&initial, &transfers, amount_bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::params::Bn254FrParams;
    type Fr = crate::ff::FrBn254;

    #[test]
    fn batch_transfer_satisfied_and_roots_move() {
        let transfers = [Transfer { from: 0, to: 1, amount: 5 }];
        let (cs, publics) =
            batch_transfer_circuit::<Bn254FrParams, 4>(&[10, 20, 30, 40], &transfers, 16);
        assert!(cs.is_satisfied());
        assert_eq!(cs.num_public, 2);
        assert_ne!(publics[0], publics[1]);
        assert_eq!(&cs.witness[1..=2], publics.as_slice());
    }

    #[test]
    fn new_root_matches_independent_replay() {
        let transfers =
            [Transfer { from: 2, to: 0, amount: 7 }, Transfer { from: 0, to: 3, amount: 9 }];
        let (_, publics) =
            batch_transfer_circuit::<Bn254FrParams, 4>(&[4, 8, 15, 16], &transfers, 16);
        // replay with plain u64 accounting and a fresh tree
        let hasher = Poseidon2::<Bn254FrParams, 4>::standard();
        let final_balances = [4 + 7 - 9, 8, 15 - 7, 16 + 9];
        let leaves: Vec<Fr> = final_balances.iter().map(|b| Fr::from_u64(*b)).collect();
        assert_eq!(MerkleTree::new(hasher, leaves).root(), publics[1]);
    }

    #[test]
    fn tampered_amount_is_rejected() {
        let transfers = [Transfer { from: 1, to: 0, amount: 3 }];
        let (mut cs, _) =
            batch_transfer_circuit::<Bn254FrParams, 4>(&[6, 6], &transfers, 8);
        assert!(cs.is_satisfied());
        // wire 3 is the first transfer's amount (after [1, old, new])
        cs.witness[3] = cs.witness[3].add(&Fr::one());
        assert!(!cs.is_satisfied());
    }

    #[test]
    #[should_panic(expected = "overdraft")]
    fn overdraft_panics_at_witness_time() {
        let transfers = [Transfer { from: 0, to: 1, amount: 11 }];
        let _ = batch_transfer_circuit::<Bn254FrParams, 4>(&[10, 0], &transfers, 8);
    }

    #[test]
    fn rollup_scenario_is_satisfied() {
        let (cs, publics) = rollup_circuit::<Bn254FrParams, 4>(2, 2, 16, 42);
        assert!(cs.is_satisfied());
        assert_eq!(publics.len(), 2);
        assert_eq!(cs.num_public, 2);
    }
}
