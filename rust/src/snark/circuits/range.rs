//! k-bit range decomposition gadget and scenario circuit.
//!
//! `range_gadget` decomposes a value into `k` boolean-constrained bits
//! and enforces that the weighted bit sum reconstructs the value, so a
//! satisfied system proves the value lies in `[0, 2^k)`. Cost is `k`
//! boolean constraints plus one linear reconstruction constraint.

use crate::ff::{Field, FieldParams, Fp};
use crate::snark::r1cs::{ConstraintSystem, LinearCombination};
use crate::util::rng::Rng;

type Lc<P, const N: usize> = LinearCombination<Fp<P, N>>;

/// Decompose `value` into `k` boolean wires (little-endian) and enforce
/// `Σ bit_i·2^i = value`. The witness bits come from the *canonical*
/// representation of the evaluated combination; if the value does not
/// fit in `k` bits the reconstruction constraint is unsatisfiable —
/// exactly the rejection the range check is for. Returns the bit wires.
///
/// Panics if `k == 0` or `k >= P::BITS` (a full-width "range check"
/// would be vacuous).
pub fn range_gadget<P: FieldParams<N>, const N: usize>(
    cs: &mut ConstraintSystem<P, N>,
    value: &Lc<P, N>,
    k: usize,
) -> Vec<usize> {
    assert!(k >= 1 && (k as u32) < P::BITS, "bit width out of range");
    let limbs = cs.eval_comb(value).to_canonical();
    let mut bits = Vec::with_capacity(k);
    let mut sum = LinearCombination::zero();
    let mut pow = Fp::<P, N>::one();
    for i in 0..k {
        let bit = (limbs[i / 64] >> (i % 64)) & 1;
        let w = cs.alloc(Fp::<P, N>::from_u64(bit));
        cs.enforce_boolean(w);
        sum = sum.plus(&LinearCombination::term(w, pow));
        pow = pow.double();
        bits.push(w);
    }
    cs.enforce_eq(&sum, value);
    bits
}

/// Domain-separation constant for the range scenario generator.
const RANGE_SEED: u64 = 0x71d8_404b_c5e2_93a6;

/// The range scenario circuit: `n_values` public values, each proven to
/// lie in `[0, 2^k)`. Values are drawn below `2^k` so the system is
/// satisfied; the public inputs are the values themselves.
pub fn range_circuit<P: FieldParams<N>, const N: usize>(
    k: usize,
    n_values: usize,
    seed: u64,
) -> (ConstraintSystem<P, N>, Vec<Fp<P, N>>) {
    assert!(k >= 1 && k <= 64, "scenario generator draws u64 values");
    let n_values = n_values.max(1);
    let mut rng = Rng::new(seed ^ RANGE_SEED);
    let values: Vec<Fp<P, N>> = (0..n_values)
        .map(|_| {
            let mask = if k == 64 { u64::MAX } else { (1u64 << k) - 1 };
            Fp::<P, N>::from_u64(rng.next_u64() & mask)
        })
        .collect();
    let mut cs = ConstraintSystem::<P, N>::new();
    let wires: Vec<usize> = values.iter().map(|v| cs.alloc_public(*v)).collect();
    for w in wires {
        range_gadget(&mut cs, &LinearCombination::var(w), k);
    }
    (cs, values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::params::Bn254FrParams;
    type Fr = crate::ff::FrBn254;

    fn check(value: Fr, k: usize) -> bool {
        let mut cs = ConstraintSystem::<Bn254FrParams, 4>::new();
        let w = cs.alloc_public(value);
        range_gadget(&mut cs, &LinearCombination::var(w), k);
        cs.is_satisfied()
    }

    #[test]
    fn accepts_in_range_rejects_beyond() {
        assert!(check(Fr::from_u64(0), 4));
        assert!(check(Fr::from_u64(15), 4));
        assert!(!check(Fr::from_u64(16), 4));
        assert!(!check(Fr::from_u64(17), 4));
    }

    #[test]
    fn rejects_huge_field_element() {
        // p − 1 is far outside any small range
        assert!(!check(Fr::zero().sub(&Fr::one()), 16));
    }

    #[test]
    fn constraint_count_is_k_plus_one_per_value() {
        let (cs, publics) = range_circuit::<Bn254FrParams, 4>(12, 5, 9);
        assert!(cs.is_satisfied());
        assert_eq!(cs.num_constraints(), 5 * 13);
        assert_eq!(cs.num_public, 5);
        assert_eq!(&cs.witness[1..=5], publics.as_slice());
    }

    #[test]
    fn gadget_works_on_compound_combinations() {
        // range-check a symbolic sum, not just a bare wire
        let mut cs = ConstraintSystem::<Bn254FrParams, 4>::new();
        let a = cs.alloc(Fr::from_u64(100));
        let b = cs.alloc(Fr::from_u64(27));
        let sum = LinearCombination::var(a).plus(&LinearCombination::var(b));
        range_gadget(&mut cs, &sum, 7);
        assert!(cs.is_satisfied());
    }
}
