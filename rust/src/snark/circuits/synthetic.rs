//! Synthetic chain circuits — the original toy workloads.
//!
//! The paper's profiling workloads are production circuits (Filecoin-scale,
//! up to 2^27 constraints); these generators produce structurally similar
//! R1CS at any size: long multiplication chains with periodic additions —
//! dense witness interaction, no shortcuts for the prover. The real
//! workloads live in the sibling modules ([`super::poseidon2`],
//! [`super::merkle`], [`super::range`], [`super::rollup`]).

use crate::ff::{Field, FieldParams, Fp};
use crate::snark::r1cs::{ConstraintSystem, LinearCombination};
use crate::util::rng::Rng;

/// A multiplication-chain circuit with `n` constraints:
/// x_{i+2} = x_{i+1} · x_i (with periodic re-randomized linear terms so
/// coefficients aren't all 1). The two chain seeds are the public inputs.
pub fn mul_chain<P: FieldParams<N>, const N: usize>(
    n: usize,
    seed: u64,
) -> ConstraintSystem<P, N> {
    let mut rng = Rng::new(seed);
    let mut cs = ConstraintSystem::<P, N>::new();
    let mut prev = cs.alloc_public(Fp::<P, N>::random(&mut rng));
    let mut cur = cs.alloc_public(Fp::<P, N>::random(&mut rng));
    for i in 0..n {
        // every 8th constraint uses an affine LHS to vary the structure
        let lhs = if i % 8 == 7 {
            let k = Fp::<P, N>::random(&mut rng);
            LinearCombination::var(cur).plus(&LinearCombination::constant(k))
        } else {
            LinearCombination::var(cur)
        };
        let out = cs.mul_lc(&lhs, &LinearCombination::var(prev));
        prev = cur;
        cur = out;
    }
    cs
}

/// A square-accumulate circuit (x ← x² + c_i), n constraints — the shape of
/// algebraic-hash chains (MiMC-like rounds, which dominate many real SNARK
/// workloads). The chain seed is the public input.
pub fn square_chain<P: FieldParams<N>, const N: usize>(
    n: usize,
    seed: u64,
) -> ConstraintSystem<P, N> {
    let mut rng = Rng::new(seed ^ SQUARE_CHAIN_SEED);
    let mut cs = ConstraintSystem::<P, N>::new();
    let mut x = cs.alloc_public(Fp::<P, N>::random(&mut rng));
    for _ in 0..n {
        let c = Fp::<P, N>::random(&mut rng);
        let next = cs.alloc(cs.witness[x].square().add(&c));
        // x·x = next − c   ⇔   ⟨x⟩·⟨x⟩ = ⟨next − c·1⟩
        let xl = LinearCombination::var(x);
        let rhs = LinearCombination::var(next).minus(&LinearCombination::constant(c));
        cs.enforce_lc(&xl, &xl, &rhs);
        x = next;
    }
    cs
}

/// Domain-separation constant for the square-chain generator.
const SQUARE_CHAIN_SEED: u64 = 0x5a5a_1357_9bdf_2468;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::params::{Bls12381FrParams, Bn254FrParams};

    #[test]
    fn mul_chain_satisfied_both_fields() {
        assert!(mul_chain::<Bn254FrParams, 4>(100, 1).is_satisfied());
        assert!(mul_chain::<Bls12381FrParams, 4>(100, 1).is_satisfied());
    }

    #[test]
    fn square_chain_satisfied() {
        let cs = square_chain::<Bn254FrParams, 4>(64, 2);
        assert!(cs.is_satisfied());
        assert_eq!(cs.num_constraints(), 64);
        assert_eq!(cs.num_variables(), 66); // 1 + input + 64 rounds
    }

    #[test]
    fn different_seeds_different_witnesses() {
        let a = mul_chain::<Bn254FrParams, 4>(10, 3);
        let b = mul_chain::<Bn254FrParams, 4>(10, 4);
        assert_ne!(a.witness[1], b.witness[1]);
    }

    #[test]
    fn tampered_chain_fails() {
        let mut cs = mul_chain::<Bn254FrParams, 4>(50, 5);
        let last = cs.witness.len() - 1;
        cs.witness[last] = cs.witness[last].add(&crate::ff::FrBn254::one());
        assert!(!cs.is_satisfied());
    }

    #[test]
    fn chains_use_the_leading_public_layout() {
        // regression: num_public comes from alloc_public now, and the
        // public wires stay pinned to w[1..=num_public]
        let cs = mul_chain::<Bn254FrParams, 4>(20, 6);
        assert_eq!(cs.num_public, 2);
        let cs = square_chain::<Bn254FrParams, 4>(20, 6);
        assert_eq!(cs.num_public, 1);
    }
}
