//! Merkle membership paths over the Poseidon2 compression function.
//!
//! A tree node is `compress(left, right)`; a membership proof is the
//! leaf, the leaf index, and one sibling per level (bottom-up). The
//! gadget allocates the index *bits* as boolean-constrained wires and
//! selects the (left, right) ordering per level with one multiplication:
//! `left = cur + b·(sib − cur)` and `right = cur + sib − left` (linear),
//! so a level costs `1 + 1 + constraints_per_permutation` constraints.

use super::poseidon2::Poseidon2;
use crate::ff::{Field, FieldParams, Fp};
use crate::snark::r1cs::{ConstraintSystem, LinearCombination};
use crate::util::rng::Rng;

type Lc<P, const N: usize> = LinearCombination<Fp<P, N>>;

/// A fully materialized Merkle tree (reference implementation, used by
/// the rollup witness generator and the property tests; membership-only
/// workloads fold synthetic paths instead of building 2^depth leaves).
#[derive(Clone, Debug)]
pub struct MerkleTree<P: FieldParams<N>, const N: usize> {
    hasher: Poseidon2<P, N>,
    /// levels[0] = leaves, levels.last() = [root]
    levels: Vec<Vec<Fp<P, N>>>,
}

impl<P: FieldParams<N>, const N: usize> MerkleTree<P, N> {
    /// Build from a power-of-two leaf vector.
    pub fn new(hasher: Poseidon2<P, N>, leaves: Vec<Fp<P, N>>) -> Self {
        assert!(leaves.len().is_power_of_two() && leaves.len() >= 2, "need 2^d >= 2 leaves");
        let mut levels = vec![leaves];
        while levels.last().unwrap().len() > 1 {
            let cur = levels.last().unwrap();
            let next: Vec<_> =
                cur.chunks(2).map(|p| hasher.compress(&p[0], &p[1])).collect();
            levels.push(next);
        }
        MerkleTree { hasher, levels }
    }

    /// Tree depth (levels below the root).
    pub fn depth(&self) -> usize {
        self.levels.len() - 1
    }

    /// The root commitment.
    pub fn root(&self) -> Fp<P, N> {
        self.levels.last().unwrap()[0]
    }

    /// Leaf value at `index`.
    pub fn leaf(&self, index: usize) -> Fp<P, N> {
        self.levels[0][index]
    }

    /// The compression instance the tree hashes with.
    pub fn hasher(&self) -> &Poseidon2<P, N> {
        &self.hasher
    }

    /// Sibling per level, bottom-up — the membership path for `index`.
    pub fn path(&self, index: usize) -> Vec<Fp<P, N>> {
        (0..self.depth()).map(|lvl| self.levels[lvl][(index >> lvl) ^ 1]).collect()
    }

    /// Replace the leaf at `index` and rehash its root path.
    pub fn update(&mut self, index: usize, leaf: Fp<P, N>) {
        self.levels[0][index] = leaf;
        for lvl in 0..self.depth() {
            let parent = (index >> lvl) / 2;
            let (l, r) = (2 * parent, 2 * parent + 1);
            let h = self.hasher.compress(&self.levels[lvl][l], &self.levels[lvl][r]);
            self.levels[lvl + 1][parent] = h;
        }
    }
}

/// Out-of-circuit root recomputation: fold `leaf` with `siblings`
/// bottom-up, taking the right slot at level ℓ when bit ℓ of `index` is
/// set. The reference the gadget is tested against.
pub fn fold_path<P: FieldParams<N>, const N: usize>(
    hasher: &Poseidon2<P, N>,
    leaf: Fp<P, N>,
    index: usize,
    siblings: &[Fp<P, N>],
) -> Fp<P, N> {
    let mut cur = leaf;
    for (lvl, sib) in siblings.iter().enumerate() {
        cur = if (index >> lvl) & 1 == 1 {
            hasher.compress(sib, &cur)
        } else {
            hasher.compress(&cur, sib)
        };
    }
    cur
}

/// The allocated wires of one membership path: boolean-constrained
/// direction bits and the sibling values, both bottom-up.
#[derive(Clone, Debug)]
pub struct PathWires {
    /// Direction bit per level (1 = current node is the right child).
    pub bits: Vec<usize>,
    /// Sibling wire per level.
    pub siblings: Vec<usize>,
}

/// Allocate (and boolean-constrain) the direction bits of `index` plus
/// the sibling wires. Shared by membership proofs and rollup updates —
/// an update reuses the *same* wires for the old-leaf and new-leaf root
/// computations, so both paths provably walk the same tree slot.
pub fn alloc_path<P: FieldParams<N>, const N: usize>(
    cs: &mut ConstraintSystem<P, N>,
    index: usize,
    siblings: &[Fp<P, N>],
) -> PathWires {
    let bits = (0..siblings.len())
        .map(|lvl| {
            let b = cs.alloc(Fp::<P, N>::from_u64(((index >> lvl) & 1) as u64));
            cs.enforce_boolean(b);
            b
        })
        .collect();
    let siblings = siblings.iter().map(|s| cs.alloc(*s)).collect();
    PathWires { bits, siblings }
}

/// In-circuit root recomputation along `path` starting from `leaf`.
/// Returns the root as a symbolic combination (callers typically
/// `enforce_eq` it against a public root wire).
pub fn root_gadget<P: FieldParams<N>, const N: usize>(
    hasher: &Poseidon2<P, N>,
    cs: &mut ConstraintSystem<P, N>,
    leaf: &Lc<P, N>,
    path: &PathWires,
) -> Lc<P, N> {
    let mut cur = leaf.clone();
    for (b, sib) in path.bits.iter().zip(&path.siblings) {
        let bl = LinearCombination::var(*b);
        let sl = LinearCombination::var(*sib);
        // left = cur + b·(sib − cur); right = cur + sib − left (linear)
        let t = cs.mul_lc(&bl, &sl.minus(&cur));
        let left = cur.plus(&LinearCombination::var(t));
        let right = cur.plus(&sl).minus(&left);
        cur = hasher.compress_gadget(cs, &left, &right);
    }
    cur
}

/// Domain-separation constant for membership circuit inputs.
const MERKLE_SEED: u64 = 0x3c77_e019_54ab_86f2;

/// The Merkle scenario circuit: `n_paths` independent membership proofs
/// of configurable `depth` against synthetic paths; the public inputs
/// are the roots. Returns the system and its claimed public inputs.
pub fn membership_circuit<P: FieldParams<N>, const N: usize>(
    depth: usize,
    n_paths: usize,
    seed: u64,
) -> (ConstraintSystem<P, N>, Vec<Fp<P, N>>) {
    assert!(depth >= 1 && depth < 64, "depth out of range");
    let n_paths = n_paths.max(1);
    let hasher = Poseidon2::<P, N>::standard();
    let mut rng = Rng::new(seed ^ MERKLE_SEED);
    struct Case<P: FieldParams<N>, const N: usize> {
        leaf: Fp<P, N>,
        index: usize,
        siblings: Vec<Fp<P, N>>,
        root: Fp<P, N>,
    }
    let cases: Vec<Case<P, N>> = (0..n_paths)
        .map(|_| {
            let leaf = Fp::<P, N>::random(&mut rng);
            let index = rng.below(1u64 << depth) as usize;
            let siblings: Vec<_> =
                (0..depth).map(|_| Fp::<P, N>::random(&mut rng)).collect();
            let root = fold_path(&hasher, leaf, index, &siblings);
            Case { leaf, index, siblings, root }
        })
        .collect();

    let mut cs = ConstraintSystem::<P, N>::new();
    let root_wires: Vec<usize> = cases.iter().map(|c| cs.alloc_public(c.root)).collect();
    for (case, root_wire) in cases.iter().zip(&root_wires) {
        let leaf = LinearCombination::var(cs.alloc(case.leaf));
        let path = alloc_path(&mut cs, case.index, &case.siblings);
        let computed = root_gadget(&hasher, &mut cs, &leaf, &path);
        cs.enforce_eq(&computed, &LinearCombination::var(*root_wire));
    }
    (cs, cases.iter().map(|c| c.root).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::params::Bn254FrParams;
    type Fr = crate::ff::FrBn254;

    fn small_hasher() -> Poseidon2<Bn254FrParams, 4> {
        Poseidon2::with_rounds(4, 8)
    }

    #[test]
    fn tree_paths_fold_to_root() {
        let h = small_hasher();
        let leaves: Vec<Fr> = (0..8).map(Fr::from_u64).collect();
        let tree = MerkleTree::new(h.clone(), leaves);
        assert_eq!(tree.depth(), 3);
        for i in 0..8 {
            assert_eq!(fold_path(&h, tree.leaf(i), i, &tree.path(i)), tree.root());
        }
    }

    #[test]
    fn update_rehashes_the_path() {
        let h = small_hasher();
        let leaves: Vec<Fr> = (0..4).map(Fr::from_u64).collect();
        let mut tree = MerkleTree::new(h.clone(), leaves.clone());
        let before = tree.root();
        tree.update(2, Fr::from_u64(99));
        assert_ne!(tree.root(), before);
        assert_eq!(fold_path(&h, Fr::from_u64(99), 2, &tree.path(2)), tree.root());
        // rebuilding from scratch agrees with the incremental update
        let mut fresh = leaves;
        fresh[2] = Fr::from_u64(99);
        assert_eq!(MerkleTree::new(h, fresh).root(), tree.root());
    }

    #[test]
    fn membership_circuit_satisfied_and_public() {
        let (cs, publics) = membership_circuit::<Bn254FrParams, 4>(3, 2, 7);
        assert!(cs.is_satisfied());
        assert_eq!(cs.num_public, 2);
        assert_eq!(&cs.witness[1..=2], publics.as_slice());
    }

    #[test]
    fn wrong_direction_bit_is_rejected() {
        let h = small_hasher();
        let mut cs = ConstraintSystem::<Bn254FrParams, 4>::new();
        let leaf_val = Fr::from_u64(5);
        let siblings = [Fr::from_u64(11), Fr::from_u64(13)];
        let root = fold_path(&h, leaf_val, 2, &siblings);
        let root_wire = cs.alloc_public(root);
        let leaf = LinearCombination::var(cs.alloc(leaf_val));
        let path = alloc_path(&mut cs, 2, &siblings);
        let computed = root_gadget(&h, &mut cs, &leaf, &path);
        cs.enforce_eq(&computed, &LinearCombination::var(root_wire));
        assert!(cs.is_satisfied());
        // flipping a direction bit walks a different slot: rejected
        let b0 = path.bits[0];
        cs.witness[b0] = Fr::one().sub(&cs.witness[b0]);
        assert!(!cs.is_satisfied());
    }
}
