//! Poseidon2-style permutation (t = 3, x⁵ S-box) — reference and gadget.
//!
//! The structure follows Poseidon2 (eprint 2023/323, the design behind
//! Ziren's Poseidon2 chip): an external round matrix M_E = circ(2,1,1)
//! applied to the input and after every *full* round (S-box on all three
//! lanes), and a cheaper internal matrix M_I = [[2,1,1],[1,2,1],[1,1,3]]
//! after every *partial* round (S-box on lane 0 only). Both matrices are
//! sum-plus-diagonal, so a layer costs 5–6 field adds, no multiplies.
//!
//! Per the repo's no-transcribed-constants rule, round constants are not
//! copied from a reference implementation: they are drawn from the
//! deterministic seeded generator ([`crate::util::rng::Rng`]) under a
//! domain-separated seed (domain tag ⊕ FNV-1a of the field name ⊕ round
//! counts), and every derivation self-checks its preconditions — x⁵ is a
//! permutation of the field (gcd(5, p−1) = 1), both round matrices are
//! invertible, and the drawn constants are nonzero and pairwise distinct.
//!
//! The circuit gadget keeps all linear structure symbolic
//! ([`LinearCombination`]) and materializes wires only inside the S-box
//! (x², x⁴, x⁵ — 3 constraints), so a full permutation costs exactly
//! `3·(3·R_F + R_P)` constraints: 240 at the standard (8, 56) rounds.

use crate::ff::{Field, FieldParams, Fp};
use crate::snark::r1cs::{ConstraintSystem, LinearCombination};
use crate::util::rng::Rng;

/// Permutation width (rate 2 + capacity 1).
pub const WIDTH: usize = 3;
/// Standard full-round count for ~255-bit fields at α = 5.
pub const FULL_ROUNDS: usize = 8;
/// Standard partial-round count for ~255-bit fields at α = 5.
pub const PARTIAL_ROUNDS: usize = 56;
/// Domain tag folded into every per-field constant seed.
pub const POSEIDON2_DOMAIN: u64 = 0x1f2e_3d4c_5b6a_7988;
/// Capacity-lane tag for 2-to-1 compression (arity marker).
pub const COMPRESS_CAP: u64 = 2;

/// A derived Poseidon2-style permutation instance over one scalar field.
#[derive(Clone, Debug)]
pub struct Poseidon2<P: FieldParams<N>, const N: usize> {
    /// First-half full-round constants, round-major.
    first: Vec<[Fp<P, N>; WIDTH]>,
    /// Partial-round constants (lane 0 only).
    partial: Vec<Fp<P, N>>,
    /// Last-half full-round constants, round-major.
    last: Vec<[Fp<P, N>; WIDTH]>,
}

/// FNV-1a of the field name — the per-field component of the seed.
fn fnv1a64(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// x⁵ is a permutation of F_p iff gcd(5, p−1) = 1. Since 2⁶⁴ ≡ 1 (mod 5),
/// p mod 5 is just the limb sum mod 5.
fn sbox_is_permutation<P: FieldParams<N>, const N: usize>() -> bool {
    let acc: u128 = P::MODULUS.iter().map(|&l| u128::from(l)).sum();
    let p_mod5 = (acc % 5) as u64;
    (p_mod5 + 4) % 5 != 0 // (p − 1) mod 5
}

/// Determinant of a 3×3 matrix of small integers, computed in-field.
fn det3<P: FieldParams<N>, const N: usize>(m: [[u64; 3]; 3]) -> Fp<P, N> {
    let e = |r: usize, c: usize| Fp::<P, N>::from_u64(m[r][c]);
    let minor = |a: Fp<P, N>, b: Fp<P, N>, c: Fp<P, N>, d: Fp<P, N>| a.mul(&d).sub(&b.mul(&c));
    let m0 = minor(e(1, 1), e(1, 2), e(2, 1), e(2, 2));
    let m1 = minor(e(1, 0), e(1, 2), e(2, 0), e(2, 2));
    let m2 = minor(e(1, 0), e(1, 1), e(2, 0), e(2, 1));
    e(0, 0).mul(&m0).sub(&e(0, 1).mul(&m1)).add(&e(0, 2).mul(&m2))
}

fn sbox<P: FieldParams<N>, const N: usize>(x: &Fp<P, N>) -> Fp<P, N> {
    let x2 = x.square();
    x2.square().mul(x)
}

/// External layer M_E = circ(2,1,1): out_i = Σs + s_i.
fn external<P: FieldParams<N>, const N: usize>(s: &[Fp<P, N>; WIDTH]) -> [Fp<P, N>; WIDTH] {
    let t = s[0].add(&s[1]).add(&s[2]);
    [t.add(&s[0]), t.add(&s[1]), t.add(&s[2])]
}

/// Internal layer M_I = [[2,1,1],[1,2,1],[1,1,3]]: out = Σs + diag·s.
fn internal<P: FieldParams<N>, const N: usize>(s: &[Fp<P, N>; WIDTH]) -> [Fp<P, N>; WIDTH] {
    let t = s[0].add(&s[1]).add(&s[2]);
    [t.add(&s[0]), t.add(&s[1]), t.add(&s[2].double())]
}

type Lc<P, const N: usize> = LinearCombination<Fp<P, N>>;

fn external_lc<P: FieldParams<N>, const N: usize>(s: &[Lc<P, N>; WIDTH]) -> [Lc<P, N>; WIDTH] {
    let t = s[0].plus(&s[1]).plus(&s[2]);
    [t.plus(&s[0]), t.plus(&s[1]), t.plus(&s[2])]
}

fn internal_lc<P: FieldParams<N>, const N: usize>(s: &[Lc<P, N>; WIDTH]) -> [Lc<P, N>; WIDTH] {
    let t = s[0].plus(&s[1]).plus(&s[2]);
    let two = Fp::<P, N>::from_u64(2);
    [t.plus(&s[0]), t.plus(&s[1]), t.plus(&s[2].scaled(&two))]
}

impl<P: FieldParams<N>, const N: usize> Poseidon2<P, N> {
    /// The standard instance: (8, 56) rounds — the usual parameterization
    /// for ~255-bit scalar fields at α = 5.
    pub fn standard() -> Self {
        Self::with_rounds(FULL_ROUNDS, PARTIAL_ROUNDS)
    }

    /// Derive an instance with explicit round counts (`rf` even ≥ 2).
    /// Reduced-round instances are for tests only — they keep the exact
    /// constraint structure at a fraction of the cost.
    pub fn with_rounds(rf: usize, rp: usize) -> Self {
        assert!(rf >= 2 && rf % 2 == 0, "full rounds must be even");
        assert!(
            sbox_is_permutation::<P, N>(),
            "x^5 is not a permutation of {} (gcd(5, p-1) != 1)",
            P::NAME
        );
        assert!(
            !det3::<P, N>([[2, 1, 1], [1, 2, 1], [1, 1, 2]]).is_zero(),
            "external round matrix is singular over {}",
            P::NAME
        );
        assert!(
            !det3::<P, N>([[2, 1, 1], [1, 2, 1], [1, 1, 3]]).is_zero(),
            "internal round matrix is singular over {}",
            P::NAME
        );
        let seed = POSEIDON2_DOMAIN ^ fnv1a64(P::NAME) ^ ((rf as u64) << 32) ^ rp as u64;
        let mut rng = Rng::new(seed);
        let half = rf / 2;
        let mut row = |rng: &mut Rng| {
            [
                Fp::<P, N>::random(rng),
                Fp::<P, N>::random(rng),
                Fp::<P, N>::random(rng),
            ]
        };
        let first: Vec<_> = (0..half).map(|_| row(&mut rng)).collect();
        let partial: Vec<_> = (0..rp).map(|_| Fp::<P, N>::random(&mut rng)).collect();
        let last: Vec<_> = (0..half).map(|_| row(&mut rng)).collect();
        let out = Poseidon2 { first, partial, last };
        out.self_check();
        out
    }

    /// Derivation self-check: all round constants nonzero and pairwise
    /// distinct (a duplicate or zero draw would weaken round separation
    /// and can only mean the generator walk is broken).
    fn self_check(&self) {
        let mut canon: Vec<[u64; N]> = Vec::new();
        for c in self.constants() {
            assert!(!c.is_zero(), "zero round constant drawn for {}", P::NAME);
            canon.push(c.to_canonical());
        }
        canon.sort_unstable();
        for w in canon.windows(2) {
            assert!(w[0] != w[1], "duplicate round constant drawn for {}", P::NAME);
        }
    }

    fn constants(&self) -> impl Iterator<Item = &Fp<P, N>> {
        self.first
            .iter()
            .chain(self.last.iter())
            .flatten()
            .chain(self.partial.iter())
    }

    /// Total round count (R_F + R_P).
    pub fn rounds(&self) -> (usize, usize) {
        (self.first.len() + self.last.len(), self.partial.len())
    }

    /// R1CS constraints one permutation costs: 3 per S-box.
    pub fn constraints_per_permutation(&self) -> usize {
        let (rf, rp) = self.rounds();
        3 * (WIDTH * rf + rp)
    }

    /// The out-of-circuit reference permutation.
    pub fn permute(&self, input: [Fp<P, N>; WIDTH]) -> [Fp<P, N>; WIDTH] {
        let mut s = external(&input);
        for rc in &self.first {
            for (x, c) in s.iter_mut().zip(rc) {
                *x = sbox(&x.add(c));
            }
            s = external(&s);
        }
        for c in &self.partial {
            s[0] = sbox(&s[0].add(c));
            s = internal(&s);
        }
        for rc in &self.last {
            for (x, c) in s.iter_mut().zip(rc) {
                *x = sbox(&x.add(c));
            }
            s = external(&s);
        }
        s
    }

    /// 2-to-1 compression: permute [l, r, cap] and truncate to lane 0.
    pub fn compress(&self, l: &Fp<P, N>, r: &Fp<P, N>) -> Fp<P, N> {
        self.permute([*l, *r, Fp::<P, N>::from_u64(COMPRESS_CAP)])[0]
    }

    /// In-circuit permutation over symbolic lane combinations. Allocates
    /// 3 wires per S-box; all matrix/constant structure stays symbolic.
    pub fn permute_gadget(
        &self,
        cs: &mut ConstraintSystem<P, N>,
        input: &[Lc<P, N>; WIDTH],
    ) -> [Lc<P, N>; WIDTH] {
        let mut s = external_lc(input);
        for rc in &self.first {
            for (x, c) in s.iter_mut().zip(rc) {
                *x = sbox_gadget(cs, &x.plus(&LinearCombination::constant(*c)));
            }
            s = external_lc(&s);
        }
        for c in &self.partial {
            s[0] = sbox_gadget(cs, &s[0].plus(&LinearCombination::constant(*c)));
            s = internal_lc(&s);
        }
        for rc in &self.last {
            for (x, c) in s.iter_mut().zip(rc) {
                *x = sbox_gadget(cs, &x.plus(&LinearCombination::constant(*c)));
            }
            s = external_lc(&s);
        }
        s
    }

    /// In-circuit 2-to-1 compression (see [`Self::compress`]).
    pub fn compress_gadget(
        &self,
        cs: &mut ConstraintSystem<P, N>,
        l: &Lc<P, N>,
        r: &Lc<P, N>,
    ) -> Lc<P, N> {
        let cap = LinearCombination::constant(Fp::<P, N>::from_u64(COMPRESS_CAP));
        let out = self.permute_gadget(cs, &[l.clone(), r.clone(), cap]);
        out[0].clone()
    }
}

/// x⁵ in 3 constraints: x·x = x², x²·x² = x⁴, x⁴·x = x⁵.
fn sbox_gadget<P: FieldParams<N>, const N: usize>(
    cs: &mut ConstraintSystem<P, N>,
    x: &Lc<P, N>,
) -> Lc<P, N> {
    let x2 = cs.mul_lc(x, x);
    let x2l = LinearCombination::var(x2);
    let x4 = cs.mul_lc(&x2l, &x2l);
    let x5 = cs.mul_lc(&LinearCombination::var(x4), x);
    LinearCombination::var(x5)
}

/// Domain-separation constant for hash-chain circuit inputs.
const HASH_CHAIN_SEED: u64 = 0x9e11_a2b4_77c3_0d51;

/// The Poseidon2 scenario circuit: `n_perms` chained permutations over a
/// seeded initial state; the single public input is the final lane-0
/// value. Returns the system and its claimed public inputs.
pub fn hash_chain_circuit<P: FieldParams<N>, const N: usize>(
    n_perms: usize,
    seed: u64,
) -> (ConstraintSystem<P, N>, Vec<Fp<P, N>>) {
    let n_perms = n_perms.max(1);
    let hasher = Poseidon2::<P, N>::standard();
    let mut rng = Rng::new(seed ^ HASH_CHAIN_SEED);
    let init = [
        Fp::<P, N>::random(&mut rng),
        Fp::<P, N>::random(&mut rng),
        Fp::<P, N>::random(&mut rng),
    ];
    // reference pass first: the public output must be allocated before
    // any private wire (the leading-publics layout)
    let mut state = init;
    for _ in 0..n_perms {
        state = hasher.permute(state);
    }
    let out = state[0];

    let mut cs = ConstraintSystem::<P, N>::new();
    let w_out = cs.alloc_public(out);
    let wires = init.map(|v| cs.alloc(v));
    let mut s = wires.map(LinearCombination::var);
    for _ in 0..n_perms {
        s = hasher.permute_gadget(&mut cs, &s);
    }
    cs.enforce_eq(&s[0], &LinearCombination::var(w_out));
    (cs, vec![out])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::params::{Bls12381FrParams, Bn254FrParams};
    type Fr = crate::ff::FrBn254;

    #[test]
    fn standard_instance_derives_and_sizes() {
        let h = Poseidon2::<Bn254FrParams, 4>::standard();
        assert_eq!(h.rounds(), (FULL_ROUNDS, PARTIAL_ROUNDS));
        assert_eq!(h.constraints_per_permutation(), 240);
        let h = Poseidon2::<Bls12381FrParams, 4>::standard();
        assert_eq!(h.constraints_per_permutation(), 240);
    }

    #[test]
    fn constants_are_field_and_round_separated() {
        let bn = Poseidon2::<Bn254FrParams, 4>::standard();
        let bls = Poseidon2::<Bls12381FrParams, 4>::standard();
        assert_ne!(bn.first[0][0].to_canonical(), bls.first[0][0].to_canonical());
        let short = Poseidon2::<Bn254FrParams, 4>::with_rounds(4, 8);
        assert_ne!(bn.first[0][0], short.first[0][0]);
    }

    #[test]
    fn permutation_is_deterministic_and_diffusing() {
        let h = Poseidon2::<Bn254FrParams, 4>::standard();
        let a = h.permute([Fr::from_u64(1), Fr::from_u64(2), Fr::from_u64(3)]);
        let b = h.permute([Fr::from_u64(1), Fr::from_u64(2), Fr::from_u64(3)]);
        assert_eq!(a, b);
        let c = h.permute([Fr::from_u64(1), Fr::from_u64(2), Fr::from_u64(4)]);
        assert!(a[0] != c[0] && a[1] != c[1] && a[2] != c[2]);
    }

    #[test]
    fn gadget_matches_reference_small_rounds() {
        let h = Poseidon2::<Bn254FrParams, 4>::with_rounds(4, 8);
        let input = [Fr::from_u64(10), Fr::from_u64(20), Fr::from_u64(30)];
        let want = h.permute(input);
        let mut cs = ConstraintSystem::<Bn254FrParams, 4>::new();
        let wires = input.map(|v| cs.alloc(v));
        let out = h.permute_gadget(&mut cs, &wires.map(LinearCombination::var));
        assert!(cs.is_satisfied());
        for (lc, want) in out.iter().zip(want) {
            assert_eq!(cs.eval_comb(lc), want);
        }
        assert_eq!(cs.num_constraints(), h.constraints_per_permutation());
    }

    #[test]
    fn hash_chain_circuit_shape() {
        let (cs, publics) = hash_chain_circuit::<Bn254FrParams, 4>(2, 42);
        assert!(cs.is_satisfied());
        assert_eq!(cs.num_public, 1);
        assert_eq!(publics.len(), 1);
        assert_eq!(cs.num_constraints(), 2 * 240 + 1);
        assert_eq!(cs.witness[1], publics[0]);
    }
}
