//! The instrumented Groth16-shaped prover — Table I's measurement rig.
//!
//! Compute phases, labeled exactly as the paper's profile buckets:
//!
//! | label    | work                                                        |
//! |----------|-------------------------------------------------------------|
//! | `msm_g1` | A-query, B1-query, L-query (size = #vars) and H-query (size ≈ domain) MSMs over 𝔾₁ |
//! | `msm_g2` | B2-query MSM over 𝔾₂ (Fp² arithmetic — ≈3× the 𝔾₁ modmul cost) |
//! | `ntt`    | the 7 domain transforms of the QAP reduction                 |
//! | `other`  | witness/LC evaluation, bookkeeping                          |

use super::qap;
use super::r1cs::ConstraintSystem;
use super::setup::Crs;
use crate::coordinator::shard::ShardPool;
use crate::ec::{Affine, CurveParams, Jacobian, ScalarLimbs};
use crate::ff::{Field, FieldParams, Fp};
use crate::msm::stream::{chunk_for_budget, msm_stream, SlicePoints, SliceScalars};
use crate::msm::{self, Backend, MsmConfig};
use crate::util::mem::{MemLedger, MemoryBudget};
use crate::util::stopwatch::Profiler;
use std::sync::Arc;

/// A (structurally) Groth16-like proof.
#[derive(Debug)]
pub struct Proof<G1: CurveParams, G2: CurveParams> {
    /// The 𝔾₁ A element.
    pub a: Jacobian<G1>,
    /// The 𝔾₂ B element.
    pub b: Jacobian<G2>,
    /// The 𝔾₁ C element.
    pub c: Jacobian<G1>,
    /// The 𝔾₁ public-input commitment: the A-query MSM restricted to
    /// the constant-one and public wires. A real Groth16 verifier
    /// derives this from its verifying key; it is carried in the proof
    /// here so [`super::verify`] can check transcript consistency (the
    /// claimed public inputs reproduce the commitment over the same
    /// CRS basis) without a pairing stack.
    pub pi: Jacobian<G1>,
}

/// Prover-time percentage split (the Table I row format).
#[derive(Clone, Debug, Default)]
pub struct ProfileBreakdown {
    /// Share of time in 𝔾₁ MSMs (A, B1, L, H queries).
    pub msm_g1_pct: f64,
    /// Share of time in the 𝔾₂ MSM (B2 query).
    pub msm_g2_pct: f64,
    /// Share of time in the QAP domain transforms.
    pub ntt_pct: f64,
    /// How the NTT share splits across the QAP pipeline's stages
    /// (3 iNTTs / 3 coset NTTs / pointwise / 1 coset iNTT).
    pub ntt_phases: qap::NttPhases,
    /// Witness evaluation and bookkeeping share.
    pub other_pct: f64,
    /// Total wall seconds of the prove call.
    pub total_s: f64,
}

/// Fixed-base precompute tables over the five CRS query vectors (the
/// prover's SRS point cache — see [`ProverConfig::point_cache`]).
struct QueryTables<G1: CurveParams, G2: CurveParams> {
    a: msm::PrecompTable<G1>,
    b1: msm::PrecompTable<G1>,
    l: msm::PrecompTable<G1>,
    h: msm::PrecompTable<G1>,
    b2: msm::PrecompTable<G2>,
}

/// Everything configurable about a [`Prover`], in one declarative value
/// consumed by [`Prover::with_config`].
///
/// [`Default`] is the Table I measurement rig: serial Pippenger, inline
/// NTTs, no GLV, no tables, no pools — identical to [`Prover::new`].
/// Builder methods refine it:
///
/// ```
/// use ifzkp::ec::{Bn254G1, Bn254G2};
/// use ifzkp::snark::prover::ProverConfig;
///
/// let cfg = ProverConfig::<Bn254G1, Bn254G2>::default()
///     .glv()          // endomorphism split on every MSM plan
///     .point_cache()  // fixed-base tables over the CRS queries
///     .ntt_threads(8);
/// ```
///
/// Unlike the deprecated `Prover::with_*` chain, construction order
/// cannot change the outcome: [`Prover::with_config`] always settles the
/// MSM plan (GLV included) *before* building any point cache, so tables
/// bake the final plan instead of snapshotting whatever the chain had
/// applied so far.
pub struct ProverConfig<G1: CurveParams, G2: CurveParams> {
    /// The plan config every MSM (G1 and G2, local and sharded) runs
    /// with. [`Self::glv`] switches it to the endomorphism split.
    pub msm: MsmConfig,
    /// The fixed local executor (ignored per-query while
    /// [`Self::auto_backend`] is set, and whenever a multi-device pool
    /// absorbs the MSM).
    pub backend: Backend,
    /// Re-resolve the executor per query via [`Backend::auto_for`]
    /// instead of using the fixed [`Self::backend`].
    pub auto_backend: bool,
    /// Thread budget for the QAP reduction's seven NTT transforms
    /// (1 = inline, the serial-measurement default).
    pub ntt_threads: usize,
    /// Build fixed-base precompute tables over all five CRS query
    /// vectors at construction and serve every query MSM from them.
    pub point_cache: bool,
    /// Sharded multi-device executors for the 𝔾₁ and 𝔾₂ MSMs; a pool
    /// with more than one device absorbs its MSMs (split per device,
    /// merged deterministically), a single-device pool behaves like the
    /// local backend.
    pub pools: Option<(Arc<ShardPool<G1>>, Arc<ShardPool<G2>>)>,
    /// When set, every query MSM runs through the bounded-memory chunk
    /// driver (`msm::stream`) under this budget instead of executing over
    /// the full resident slice at once. Proofs are bit-identical; the
    /// point cache is bypassed while set (resident Θ(m·2^k) tables are
    /// antithetical to a byte budget). For a CRS that is never
    /// materialized at all, use `snark::stream::prove_streaming`.
    pub streaming: Option<MemoryBudget>,
}

// Manual impls: derives would demand `G1: Default/Clone` etc. even
// though the type parameters only appear behind `Arc`.
impl<G1: CurveParams, G2: CurveParams> Default for ProverConfig<G1, G2> {
    fn default() -> Self {
        ProverConfig {
            msm: MsmConfig::default(),
            backend: Backend::Pippenger,
            auto_backend: false,
            ntt_threads: 1,
            point_cache: false,
            pools: None,
            streaming: None,
        }
    }
}

impl<G1: CurveParams, G2: CurveParams> Clone for ProverConfig<G1, G2> {
    fn clone(&self) -> Self {
        ProverConfig {
            msm: self.msm,
            backend: self.backend,
            auto_backend: self.auto_backend,
            ntt_threads: self.ntt_threads,
            point_cache: self.point_cache,
            pools: self.pools.clone(),
            streaming: self.streaming,
        }
    }
}

impl<G1: CurveParams, G2: CurveParams> ProverConfig<G1, G2> {
    /// Switch every MSM plan to the GLV endomorphism fast path (scalars
    /// split into two half-width parts against the doubled (P, φ(P))
    /// set). Proofs are unchanged; curves without endomorphism
    /// parameters fall back to full-width plans transparently.
    pub fn glv(mut self) -> Self {
        self.msm = self.msm.glv();
        self
    }

    /// Fix the local MSM executor (clears [`Self::auto_backend`]).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self.auto_backend = false;
        self
    }

    /// Resolve the executor per MSM via [`Backend::auto_for`] (size-,
    /// curve- and plan-aware) instead of fixing one.
    pub fn auto_backend(mut self) -> Self {
        self.auto_backend = true;
        self
    }

    /// Run the QAP reduction's NTTs over `threads` OS threads (clamped
    /// to at least 1). Bit-identical output at any width.
    pub fn ntt_threads(mut self, threads: usize) -> Self {
        self.ntt_threads = threads.max(1);
        self
    }

    /// Build fixed-base tables over the CRS queries at construction and
    /// serve the query MSMs from them (bit-identical to live points).
    pub fn point_cache(mut self) -> Self {
        self.point_cache = true;
        self
    }

    /// Attach sharded multi-device pools for the 𝔾₁ and 𝔾₂ MSMs.
    pub fn pools(mut self, g1: Arc<ShardPool<G1>>, g2: Arc<ShardPool<G2>>) -> Self {
        self.pools = Some((g1, g2));
        self
    }

    /// Run every query MSM through the bounded-memory chunk driver under
    /// `budget` (see [`Self::streaming`]). Bit-identical proofs.
    pub fn streaming(mut self, budget: MemoryBudget) -> Self {
        self.streaming = Some(budget);
        self
    }
}

/// The prover, bound to a curve family. All five MSMs route through the
/// shared kernel dispatch ([`msm::execute`]). Configure it declaratively
/// with [`ProverConfig`] + [`Self::with_config`] (serial Pippenger by
/// default so the Table I profile measures single-thread phase shares,
/// as the paper's does); when a configured pool holds more than one
/// device, its MSMs submit through the sharded path (split per device,
/// merged deterministically) instead of the local backend.
pub struct Prover<G1: CurveParams, G2: CurveParams, P: FieldParams<4>> {
    /// The CRS query vectors the MSMs consume.
    pub crs: Crs<G1, G2>,
    /// The plan config every MSM runs with (see [`ProverConfig::glv`]).
    pub msm_cfg: MsmConfig,
    /// The local executor (ignored when a multi-device pool handles an MSM).
    pub backend: Backend,
    /// When set, every MSM re-resolves its executor per query via
    /// [`Backend::auto_for`] (size-, curve- and plan-aware: the
    /// chunk-parallel backend once the host's thread budget exceeds the
    /// plan's window count) instead of using the fixed [`Self::backend`].
    pub auto_backend: bool,
    /// Sharded executor for the 𝔾₁ MSMs (A, B1, L, H queries).
    pub pool_g1: Option<Arc<ShardPool<G1>>>,
    /// Sharded executor for the 𝔾₂ MSM (B2 query).
    pub pool_g2: Option<Arc<ShardPool<G2>>>,
    /// Thread budget for the QAP reduction's seven NTT transforms
    /// (1 = inline, the Table I serial-measurement default; see
    /// [`ProverConfig::ntt_threads`]).
    pub ntt_threads: usize,
    /// Bounded-memory mode: when set, every query MSM streams its
    /// (resident) CRS slice in budget-sized chunks through
    /// `msm::stream::msm_stream` instead of executing over the whole
    /// slice at once, and the point cache is bypassed (see
    /// [`ProverConfig::streaming`]). Proofs are bit-identical.
    pub streaming: Option<MemoryBudget>,
    /// Fixed-base tables over the CRS queries; `None` = live-point MSMs.
    /// Served only while compatible with the current [`Self::msm_cfg`].
    point_cache: Option<QueryTables<G1, G2>>,
    _p: std::marker::PhantomData<P>,
}

impl<G1, G2, P> Prover<G1, G2, P>
where
    G1: CurveParams,
    G2: CurveParams,
    P: FieldParams<4>,
{
    /// A serial-Pippenger prover over a CRS (the Table I measurement rig).
    /// Equivalent to [`Self::with_config`] with [`ProverConfig::default`].
    pub fn new(crs: Crs<G1, G2>) -> Self {
        Self::with_config(crs, ProverConfig::default())
    }

    /// Build a prover from a declarative [`ProverConfig`].
    ///
    /// The plan is settled first (GLV included), then the point cache —
    /// if requested — is built against that final plan, so the old
    /// builder chain's ordering pitfall (`with_point_cache().with_glv()`
    /// silently disabling the just-built tables) cannot be expressed.
    pub fn with_config(crs: Crs<G1, G2>, cfg: ProverConfig<G1, G2>) -> Self {
        let (pool_g1, pool_g2) = match cfg.pools {
            Some((g1, g2)) => (Some(g1), Some(g2)),
            None => (None, None),
        };
        let prover = Prover {
            crs,
            msm_cfg: cfg.msm,
            backend: cfg.backend,
            auto_backend: cfg.auto_backend,
            pool_g1,
            pool_g2,
            ntt_threads: cfg.ntt_threads.max(1),
            streaming: cfg.streaming,
            point_cache: None,
            _p: std::marker::PhantomData,
        };
        if cfg.point_cache {
            prover.build_point_cache()
        } else {
            prover
        }
    }

    /// Run the QAP reduction's NTT transforms over `threads` OS threads
    /// (through the domain's cached twiddle plan — see
    /// [`crate::ntt::NttPlan`]). The h coefficients, and therefore the
    /// proof, are bit-identical for every thread count; only the NTT
    /// phase's wall time changes.
    #[deprecated(note = "use ProverConfig::ntt_threads with Prover::with_config")]
    pub fn with_ntt_threads(mut self, threads: usize) -> Self {
        self.ntt_threads = threads.max(1);
        self
    }

    /// Same prover, different MSM executor.
    #[deprecated(note = "use ProverConfig::backend with Prover::with_config")]
    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self.auto_backend = false;
        self
    }

    /// Resolve the executor per MSM instead of fixing one: each query
    /// runs [`Backend::auto_for`] over its own length and the prover's
    /// plan config, so on wide hosts the G1/G2 MSMs land on the
    /// chunk-parallel backend whenever threads exceed the plan's window
    /// count (e.g. any GLV plan past 11 threads on BN254).
    #[deprecated(note = "use ProverConfig::auto_backend with Prover::with_config")]
    pub fn with_auto_backend(mut self) -> Self {
        self.auto_backend = true;
        self
    }

    /// Switch every MSM (G1 and G2, local and sharded) to the GLV
    /// endomorphism fast path: scalars split into two half-width parts
    /// against the doubled (P, φ(P)) point set, halving the window passes
    /// per MSM. The proof is unchanged — curves without endomorphism
    /// parameters fall back to full-width plans transparently.
    #[deprecated(note = "use ProverConfig::glv with Prover::with_config")]
    pub fn with_glv(mut self) -> Self {
        self.msm_cfg = self.msm_cfg.glv();
        self
    }

    /// Build fixed-base precompute tables over all five CRS query vectors
    /// ([`msm::PrecompTable`]) and serve every query MSM from them.
    ///
    /// Tables snapshot the current [`Self::msm_cfg`]: call after
    /// [`Self::with_glv`] to bake the endomorphism split into the tables.
    /// A later plan change disables them (compatibility gate) rather than
    /// serving entries from the wrong plan — the ordering pitfall
    /// [`Self::with_config`] exists to remove.
    #[deprecated(note = "use ProverConfig::point_cache with Prover::with_config")]
    pub fn with_point_cache(self) -> Self {
        self.build_point_cache()
    }

    /// Build fixed-base precompute tables over all five CRS query vectors
    /// against the *current* plan config and serve every query MSM from
    /// them: the fill loop reads pre-shifted window multiples straight
    /// into buckets, so the per-proof hot path issues zero point
    /// doublings in the fill and combine phases. The build cost is paid
    /// here, once — the SRS is fixed across proofs, so tables amortize
    /// exactly like the CRS synthesis itself. Proofs are bit-identical
    /// to the live-point path.
    fn build_point_cache(mut self) -> Self {
        let cfg = &self.msm_cfg;
        self.point_cache = Some(QueryTables {
            a: msm::PrecompTable::build(&self.crs.a_query, cfg),
            b1: msm::PrecompTable::build(&self.crs.b1_query, cfg),
            l: msm::PrecompTable::build(&self.crs.l_query, cfg),
            h: msm::PrecompTable::build(&self.crs.h_query, cfg),
            b2: msm::PrecompTable::build(&self.crs.b2_query, cfg),
        });
        self
    }

    /// Run every query MSM through the bounded-memory chunk driver under
    /// `budget`: each chunk's payload bytes are charged to an enforced
    /// ledger before it is copied out of the CRS, so the MSM working set
    /// (beyond the resident CRS itself) stays within the budget. The
    /// proof is bit-identical to the plain path at every budget that
    /// admits one element.
    ///
    /// This streams a *resident* CRS; to prove without ever materializing
    /// the CRS, use `snark::stream::prove_streaming` with a
    /// `StreamingSrs`.
    pub fn with_streaming(mut self, budget: MemoryBudget) -> Self {
        self.streaming = Some(budget);
        self
    }

    /// The cached table for one query, if present and still built for the
    /// prover's current plan config. Streaming mode bypasses tables: they
    /// are Θ(m·2^k) resident, which defeats the byte budget.
    fn cached<'a, C: CurveParams>(
        &'a self,
        pick: impl FnOnce(&'a QueryTables<G1, G2>) -> &'a msm::PrecompTable<C>,
    ) -> Option<&'a msm::PrecompTable<C>> {
        if self.streaming.is_some() {
            return None;
        }
        self.point_cache.as_ref().map(pick).filter(|t| t.compatible_with(&self.msm_cfg))
    }

    /// Attach multi-device pools. MSMs submit through the sharded path
    /// whenever the relevant pool registers more than one device; a
    /// single-device pool behaves like the plain backend, and an atomic
    /// shard-group failure falls back to the local backend (with a
    /// warning) rather than failing the proof.
    #[deprecated(note = "use ProverConfig::pools with Prover::with_config")]
    pub fn with_pools(mut self, g1: Arc<ShardPool<G1>>, g2: Arc<ShardPool<G2>>) -> Self {
        self.pool_g1 = Some(g1);
        self.pool_g2 = Some(g2);
        self
    }

    /// One query MSM through the bounded-memory chunk driver: chunk size
    /// is what `budget` admits, the executor resolves over the *chunk*
    /// length (each chunk is what actually executes), and every chunk's
    /// bytes are charged to an enforced ledger. Bit-identical to the
    /// resident execute for any chunking (the ascending-order fold is the
    /// contiguous case of `partial::merge`).
    fn msm_streamed<C: CurveParams>(
        &self,
        points: &[Affine<C>],
        scalars: &[ScalarLimbs],
        budget: MemoryBudget,
    ) -> Jacobian<C> {
        let chunk = chunk_for_budget::<C>(budget.get());
        assert!(
            chunk > 0,
            "streaming budget of {} bytes cannot hold one {} element; \
             use snark::stream::prove_streaming for a typed error",
            budget.get(),
            C::NAME
        );
        let backend = if self.auto_backend {
            Backend::auto_for::<C>(chunk.min(points.len()), &self.msm_cfg)
        } else {
            self.backend
        };
        let ledger = MemLedger::new(budget);
        msm_stream(
            &mut SlicePoints::new(points),
            &mut SliceScalars::new(scalars),
            backend,
            &self.msm_cfg,
            chunk,
            &ledger,
        )
        .expect("slice streams cannot fail and the budget admits the chunk size")
    }

    fn msm_g1(&self, points: &[Affine<G1>], scalars: &[ScalarLimbs]) -> Jacobian<G1> {
        if let Some(pool) = &self.pool_g1 {
            if pool.device_count() > 1 {
                match pool.execute(points, scalars, &self.msm_cfg) {
                    Ok(out) => return out,
                    // an atomic shard-group failure must not kill the
                    // prover: fall back to the local backend
                    Err(e) => eprintln!("[WARN] sharded G1 MSM failed, running locally: {e:#}"),
                }
            }
        }
        if let Some(budget) = self.streaming {
            return self.msm_streamed(points, scalars, budget);
        }
        let backend = if self.auto_backend {
            Backend::auto_for::<G1>(points.len(), &self.msm_cfg)
        } else {
            self.backend
        };
        msm::execute(backend, points, scalars, &self.msm_cfg)
    }

    fn msm_g2(&self, points: &[Affine<G2>], scalars: &[ScalarLimbs]) -> Jacobian<G2> {
        if let Some(pool) = &self.pool_g2 {
            if pool.device_count() > 1 {
                match pool.execute(points, scalars, &self.msm_cfg) {
                    Ok(out) => return out,
                    Err(e) => eprintln!("[WARN] sharded G2 MSM failed, running locally: {e:#}"),
                }
            }
        }
        if let Some(budget) = self.streaming {
            return self.msm_streamed(points, scalars, budget);
        }
        let backend = if self.auto_backend {
            Backend::auto_for::<G2>(points.len(), &self.msm_cfg)
        } else {
            self.backend
        };
        msm::execute(backend, points, scalars, &self.msm_cfg)
    }

    /// Run the prover pipeline over a satisfied constraint system,
    /// recording per-phase time. Panics if witness sizes don't match the
    /// CRS (programmer error in workload setup).
    pub fn prove(
        &self,
        cs: &ConstraintSystem<P, 4>,
    ) -> (Proof<G1, G2>, ProfileBreakdown) {
        let mut prof = Profiler::new();

        // -- other: witness/LC evaluation ---------------------------------
        let (a_evals, b_evals, c_evals) = prof.time("other", || cs.constraint_evals());

        // -- ntt: QAP h(x) (all 7 transforms through one cached plan) ------
        let (qapw, ntt_phases) = prof
            .time("ntt", || {
                qap::compute_h_with(&a_evals, &b_evals, &c_evals, self.ntt_threads)
            })
            .expect("domain within field 2-adicity");

        // -- msm scalars ----------------------------------------------------
        let witness_scalars: Vec<ScalarLimbs> = prof.time("other", || {
            cs.witness.iter().map(|w| w.to_canonical()).collect()
        });
        let h_scalars: Vec<ScalarLimbs> = prof.time("other", || {
            qapw.h_coeffs.iter().map(Fp::to_canonical).collect()
        });

        let nv = cs.num_variables();
        assert!(self.crs.a_query.len() >= nv, "CRS smaller than witness");

        // -- msm_g1: A, B1, L, H (table-fed when a point cache is built,
        // else sharded across the pool when present) -----------------------
        let a_msm = prof.time("msm_g1", || match self.cached(|t| &t.a) {
            Some(t) => t.msm_range(0, &witness_scalars),
            None => self.msm_g1(&self.crs.a_query[..nv], &witness_scalars),
        });
        let _b1_msm = prof.time("msm_g1", || match self.cached(|t| &t.b1) {
            Some(t) => t.msm_range(0, &witness_scalars),
            None => self.msm_g1(&self.crs.b1_query[..nv], &witness_scalars),
        });
        let l_start = 1 + cs.num_public;
        let l_msm = prof.time("msm_g1", || match self.cached(|t| &t.l) {
            Some(t) => t.msm_range(l_start, &witness_scalars[l_start..nv]),
            None => self.msm_g1(&self.crs.l_query[l_start..nv], &witness_scalars[l_start..]),
        });
        let h_len = h_scalars.len().min(self.crs.h_query.len());
        let h_msm = prof.time("msm_g1", || match self.cached(|t| &t.h) {
            Some(t) => t.msm_range(0, &h_scalars[..h_len]),
            None => self.msm_g1(&self.crs.h_query[..h_len], &h_scalars[..h_len]),
        });

        // -- msm_g2: B2 -----------------------------------------------------
        let b2_msm = prof.time("msm_g2", || match self.cached(|t| &t.b2) {
            Some(t) => t.msm_range(0, &witness_scalars),
            None => self.msm_g2(&self.crs.b2_query[..nv], &witness_scalars),
        });

        // -- other: public-input commitment + final assembly ----------------
        // π is a (1 + num_public)-point MSM over the A-query prefix — far
        // too small to matter in the phase profile, so it is charged to
        // "other" and always runs the serial executor: routing it through
        // pools/streaming would perturb their accounting (shard-group
        // counters, chunk high-water pins) for no measurable gain. Every
        // backend is bit-identical, so the choice is invisible in proofs.
        let proof = prof.time("other", || {
            let pi = msm::execute(
                Backend::Pippenger,
                &self.crs.a_query[..l_start],
                &witness_scalars[..l_start],
                &self.msm_cfg,
            );
            Proof { a: a_msm, b: b2_msm, c: l_msm.add(&h_msm), pi }
        });

        (proof, breakdown(&prof, ntt_phases))
    }
}

fn breakdown(prof: &Profiler, ntt_phases: qap::NttPhases) -> ProfileBreakdown {
    let total = prof.total().as_secs_f64();
    let pct = |label: &str| {
        if total > 0.0 {
            100.0 * prof.get(label).as_secs_f64() / total
        } else {
            0.0
        }
    };
    ProfileBreakdown {
        msm_g1_pct: pct("msm_g1"),
        msm_g2_pct: pct("msm_g2"),
        ntt_pct: pct("ntt"),
        ntt_phases,
        other_pct: pct("other"),
        total_s: total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{Bn254G1, Bn254G2};
    use crate::ff::params::Bn254FrParams;
    use crate::snark::{circuits, setup::CrsBn254};

    fn small_cs() -> ConstraintSystem<Bn254FrParams, 4> {
        circuits::mul_chain::<Bn254FrParams, 4>(200, 77)
    }

    // deterministic synthesis: every call over the same cs yields the
    // same CRS, so provers built separately are comparable bit-for-bit
    fn crs_for(cs: &ConstraintSystem<Bn254FrParams, 4>) -> Crs<Bn254G1, Bn254G2> {
        let domain_n = (cs.num_constraints().max(2)).next_power_of_two();
        CrsBn254::synthesize(cs.num_variables(), domain_n, 78)
    }

    fn small_prover() -> (Prover<Bn254G1, Bn254G2, Bn254FrParams>, ConstraintSystem<Bn254FrParams, 4>)
    {
        let cs = small_cs();
        let crs = crs_for(&cs);
        (Prover::new(crs), cs)
    }

    fn config_prover(
        cfg: ProverConfig<Bn254G1, Bn254G2>,
    ) -> (Prover<Bn254G1, Bn254G2, Bn254FrParams>, ConstraintSystem<Bn254FrParams, 4>) {
        let cs = small_cs();
        let crs = crs_for(&cs);
        (Prover::with_config(crs, cfg), cs)
    }

    #[test]
    fn prover_runs_and_profiles() {
        let (prover, cs) = small_prover();
        assert!(cs.is_satisfied());
        let (proof, prof) = prover.prove(&cs);
        assert!(!proof.a.is_infinity());
        assert!(!proof.b.is_infinity());
        assert!(!proof.c.is_infinity());
        assert!(!proof.pi.is_infinity());
        let sum = prof.msm_g1_pct + prof.msm_g2_pct + prof.ntt_pct + prof.other_pct;
        assert!((sum - 100.0).abs() < 1.0, "percentages sum to {sum}");
        assert!(prof.total_s > 0.0);
    }

    #[test]
    fn msm_dominates_like_table_i() {
        // Table I: MSM G1+G2 ≈ 88–92% of prover time. At small test sizes
        // the exact split shifts, but MSM must already dominate.
        let (prover, cs) = small_prover();
        let (_, prof) = prover.prove(&cs);
        assert!(
            prof.msm_g1_pct + prof.msm_g2_pct > 60.0,
            "msm share {} + {}",
            prof.msm_g1_pct,
            prof.msm_g2_pct
        );
    }

    #[test]
    fn g2_msm_costs_more_than_any_single_g1_msm() {
        // Fp² Karatsuba = 3 Fp muls ⇒ the single G2 MSM should outweigh
        // each individual G1 MSM of the same length (Table I's reason the
        // G2 column exceeds G1 despite 4 G1 MSMs vs 1 G2).
        let (prover, cs) = small_prover();
        let (_, prof) = prover.prove(&cs);
        // 4 G1 MSMs vs 1 G2 MSM: per-MSM G2 > per-MSM G1 requires
        // g2_pct > g1_pct / 4 with margin.
        assert!(prof.msm_g2_pct > prof.msm_g1_pct / 4.0);
    }

    #[test]
    fn proof_identical_across_backends() {
        // the dispatch layer must be invisible in the output
        let (prover, cs) = small_prover();
        let (p1, _) = prover.prove(&cs);
        let (prover2, _) =
            config_prover(ProverConfig::default().backend(Backend::BatchAffineParallel {
                threads: 2,
            }));
        let (p2, _) = prover2.prove(&cs);
        assert!(p1.a.eq_point(&p2.a));
        assert!(p1.b.eq_point(&p2.b));
        assert!(p1.c.eq_point(&p2.c));
    }

    #[test]
    fn proof_identical_with_auto_backend() {
        // per-query auto resolution (chunked on wide hosts, window-
        // parallel otherwise) must be invisible in the proof
        let (prover, cs) = small_prover();
        let (p1, _) = prover.prove(&cs);
        let (prover2, _) = config_prover(ProverConfig::default().auto_backend());
        let (p2, _) = prover2.prove(&cs);
        assert!(p1.a.eq_point(&p2.a));
        assert!(p1.b.eq_point(&p2.b));
        assert!(p1.c.eq_point(&p2.c));
        // the explicit chunked backend agrees too, at threads ≫ windows
        let (prover3, _) =
            config_prover(ProverConfig::default().backend(Backend::Chunked { threads: 32 }));
        let (p3, _) = prover3.prove(&cs);
        assert!(p1.a.eq_point(&p3.a));
        assert!(p1.c.eq_point(&p3.c));
    }

    #[test]
    fn proof_identical_with_glv() {
        // the GLV fast path must be invisible in the proof, for both the
        // G1 MSMs and the Fp²-based G2 MSM
        let (prover, cs) = small_prover();
        let (p1, _) = prover.prove(&cs);
        let (prover2, _) = config_prover(ProverConfig::default().glv());
        let (p2, _) = prover2.prove(&cs);
        assert!(p1.a.eq_point(&p2.a));
        assert!(p1.b.eq_point(&p2.b));
        assert!(p1.c.eq_point(&p2.c));
    }

    #[test]
    fn proof_identical_with_point_cache() {
        // the table-fed fixed-base path must be invisible in the proof —
        // on the plain plan and with the GLV split baked into the tables
        let (prover, cs) = small_prover();
        let (p1, _) = prover.prove(&cs);
        let (prover2, _) = config_prover(ProverConfig::default().point_cache());
        let (p2, _) = prover2.prove(&cs);
        assert!(p1.a.eq_point(&p2.a));
        assert!(p1.b.eq_point(&p2.b));
        assert!(p1.c.eq_point(&p2.c));
        let (prover3, _) = config_prover(ProverConfig::default().glv().point_cache());
        let (p3, _) = prover3.prove(&cs);
        assert!(p1.a.eq_point(&p3.a));
        assert!(p1.b.eq_point(&p3.b));
        assert!(p1.c.eq_point(&p3.c));
        // a plan change AFTER the build must disable the tables (the
        // compatibility gate), not serve entries from the wrong plan —
        // the config path can't express that order, so mutate directly
        let (mut prover4, _) = config_prover(ProverConfig::default().point_cache());
        prover4.msm_cfg = prover4.msm_cfg.glv();
        let (p4, _) = prover4.prove(&cs);
        assert!(p1.a.eq_point(&p4.a));
        assert!(p1.b.eq_point(&p4.b));
        assert!(p1.c.eq_point(&p4.c));
    }

    #[test]
    fn proof_identical_with_parallel_ntt_and_phases_recorded() {
        // the NTT thread budget must be invisible in the proof, and the
        // breakdown's NTT phase split must account for the ntt bucket
        let (prover, cs) = small_prover();
        let (p1, prof1) = prover.prove(&cs);
        assert!(prof1.ntt_phases.total_s() > 0.0, "{prof1:?}");
        // the phase split sums to (about) the whole ntt bucket — the
        // padding/copy overhead outside the four phases is small
        let ntt_s = prof1.total_s * prof1.ntt_pct / 100.0;
        assert!(prof1.ntt_phases.total_s() <= ntt_s * 1.001 + 1e-9, "{prof1:?}");
        let (prover2, _) = config_prover(ProverConfig::default().ntt_threads(8));
        let (p2, _) = prover2.prove(&cs);
        assert!(p1.a.eq_point(&p2.a));
        assert!(p1.b.eq_point(&p2.b));
        assert!(p1.c.eq_point(&p2.c));
    }

    #[test]
    fn proof_identical_with_sharded_pools() {
        // the multi-device sharded path must be invisible in the output
        let (prover, cs) = small_prover();
        let (p1, _) = prover.prove(&cs);
        let pool_g1 = Arc::new(ShardPool::<Bn254G1>::native(3, 1));
        let pool_g2 = Arc::new(ShardPool::<Bn254G2>::native(2, 1));
        let (prover2, _) =
            config_prover(ProverConfig::default().pools(pool_g1.clone(), pool_g2.clone()));
        let (p2, _) = prover2.prove(&cs);
        assert!(p1.a.eq_point(&p2.a));
        assert!(p1.b.eq_point(&p2.b));
        assert!(p1.c.eq_point(&p2.c));
        // the pools really absorbed the MSMs: 4 G1 (A, B1, L, H), 1 G2 (B2)
        assert_eq!(pool_g1.counters.snapshot().shard_groups, 4);
        assert_eq!(pool_g2.counters.snapshot().shard_groups, 1);
    }

    #[test]
    fn prover_falls_back_when_pool_fails_atomically() {
        use crate::coordinator::shard::{PoolDevice, ShardPolicy};
        use std::sync::atomic::AtomicUsize;
        let flaky = || PoolDevice::Flaky {
            failures: Arc::new(AtomicUsize::new(usize::MAX / 2)), // never heals
            threads: 1,
        };
        let (prover, cs) = small_prover();
        let (p1, _) = prover.prove(&cs);
        let (prover2, _) = config_prover(ProverConfig::default().pools(
            Arc::new(ShardPool::<Bn254G1>::new(vec![flaky(), flaky()], ShardPolicy::ChunkPoints)),
            Arc::new(ShardPool::<Bn254G2>::new(vec![flaky(), flaky()], ShardPolicy::ChunkPoints)),
        ));
        // every sharded MSM fails atomically → local-backend fallback, not
        // a panic — and the proof is unchanged
        let (p2, _) = prover2.prove(&cs);
        assert!(p1.a.eq_point(&p2.a));
        assert!(p1.b.eq_point(&p2.b));
        assert!(p1.c.eq_point(&p2.c));
    }

    #[test]
    #[allow(deprecated)]
    fn config_path_bit_identical_to_deprecated_builder_chain() {
        // the deprecated with_* chain and the one-shot config must build
        // equivalent provers: same proof, bit for bit, under a config
        // exercising every knob the chain could set
        let cs = small_cs();
        assert!(cs.is_satisfied());
        let old = Prover::<Bn254G1, Bn254G2, Bn254FrParams>::new(crs_for(&cs))
            .with_backend(Backend::BatchAffineParallel { threads: 2 })
            .with_ntt_threads(4)
            .with_glv()
            .with_point_cache();
        let new = Prover::with_config(
            crs_for(&cs),
            ProverConfig::default()
                .backend(Backend::BatchAffineParallel { threads: 2 })
                .ntt_threads(4)
                .glv()
                .point_cache(),
        );
        let (po, _) = old.prove(&cs);
        let (pn, _) = new.prove(&cs);
        assert!(po.a.eq_point(&pn.a));
        assert!(po.b.eq_point(&pn.b));
        assert!(po.c.eq_point(&pn.c));
    }

    #[test]
    fn proof_identical_with_streaming() {
        use crate::util::mem::MemoryBudget;
        // the bounded-memory chunk driver must be invisible in the proof:
        // tiny budget (few points per chunk), generous budget, the
        // deprecated-style with_streaming method, and streaming stacked
        // on GLV + auto-backend
        let (prover, cs) = small_prover();
        let (p1, _) = prover.prove(&cs);
        for budget in [MemoryBudget::bytes(8 * 160), MemoryBudget::mib(64)] {
            let (prover2, _) = config_prover(ProverConfig::default().streaming(budget));
            let (p2, _) = prover2.prove(&cs);
            assert!(p1.a.eq_point(&p2.a), "budget {}", budget.get());
            assert!(p1.b.eq_point(&p2.b), "budget {}", budget.get());
            assert!(p1.c.eq_point(&p2.c), "budget {}", budget.get());
        }
        let (prover3, _) = config_prover(ProverConfig::default());
        let (p3, _) = prover3.with_streaming(MemoryBudget::bytes(16 * 160)).prove(&cs);
        assert!(p1.a.eq_point(&p3.a));
        assert!(p1.b.eq_point(&p3.b));
        assert!(p1.c.eq_point(&p3.c));
        let (prover4, _) = config_prover(
            ProverConfig::default().glv().auto_backend().streaming(MemoryBudget::bytes(32 * 160)),
        );
        let (p4, _) = prover4.prove(&cs);
        assert!(p1.a.eq_point(&p4.a));
        assert!(p1.b.eq_point(&p4.b));
        assert!(p1.c.eq_point(&p4.c));
    }

    #[test]
    fn streaming_bypasses_point_cache() {
        use crate::util::mem::MemoryBudget;
        // tables are Θ(m·2^k) resident — streaming mode must ignore them
        // and still produce the identical proof
        let (prover, cs) = small_prover();
        let (p1, _) = prover.prove(&cs);
        let (prover2, _) = config_prover(
            ProverConfig::default().point_cache().streaming(MemoryBudget::bytes(16 * 160)),
        );
        assert!(prover2.cached(|t| &t.a).is_none(), "cache must be bypassed while streaming");
        let (p2, _) = prover2.prove(&cs);
        assert!(p1.a.eq_point(&p2.a));
        assert!(p1.b.eq_point(&p2.b));
        assert!(p1.c.eq_point(&p2.c));
    }

    #[test]
    fn proof_deterministic_for_fixed_inputs() {
        let (prover, cs) = small_prover();
        let (p1, _) = prover.prove(&cs);
        let (p2, _) = prover.prove(&cs);
        assert!(p1.a.eq_point(&p2.a));
        assert!(p1.b.eq_point(&p2.b));
        assert!(p1.c.eq_point(&p2.c));
        assert!(p1.pi.eq_point(&p2.pi));
    }

    #[test]
    fn pi_commits_to_the_public_prefix() {
        // π must equal the A-query MSM over [1, publics..] and nothing
        // else — the anchor the verifier recomputes
        let (prover, cs) = small_prover();
        let (proof, _) = prover.prove(&cs);
        let l_start = 1 + cs.num_public;
        let scalars: Vec<_> = cs.witness[..l_start].iter().map(|w| w.to_canonical()).collect();
        let want = msm::execute(
            Backend::Naive,
            &prover.crs.a_query[..l_start],
            &scalars,
            &MsmConfig::default(),
        );
        assert!(proof.pi.eq_point(&want));
    }
}
