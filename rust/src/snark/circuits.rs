//! Synthetic circuits for prover workloads.
//!
//! The paper's profiling workloads are production circuits (Filecoin-scale,
//! up to 2^27 constraints); these generators produce structurally similar
//! R1CS at any size: long multiplication chains with periodic additions —
//! dense witness interaction, no shortcuts for the prover.

use super::r1cs::ConstraintSystem;
use crate::ff::{Field, FieldParams, Fp};
use crate::util::rng::Rng;

/// A multiplication-chain circuit with `n` constraints:
/// x_{i+2} = x_{i+1} · x_i (with periodic re-randomized linear terms so
/// coefficients aren't all 1).
pub fn mul_chain<P: FieldParams<N>, const N: usize>(
    n: usize,
    seed: u64,
) -> ConstraintSystem<P, N> {
    let mut rng = Rng::new(seed);
    let mut cs = ConstraintSystem::<P, N>::new();
    let mut prev = cs.alloc(Fp::<P, N>::random(&mut rng));
    let mut cur = cs.alloc(Fp::<P, N>::random(&mut rng));
    cs.num_public = 2;
    for i in 0..n {
        // every 8th constraint uses an affine LHS to vary the structure
        if i % 8 == 7 {
            let k = Fp::<P, N>::random(&mut rng);
            let lhs = cs.witness[cur].add(&k);
            let out = cs.alloc(lhs.mul(&cs.witness[prev]));
            cs.enforce(
                vec![(cur, Fp::<P, N>::one()), (0, k)],
                vec![(prev, Fp::<P, N>::one())],
                vec![(out, Fp::<P, N>::one())],
            );
            prev = cur;
            cur = out;
        } else {
            let out = cs.alloc(cs.witness[cur].mul(&cs.witness[prev]));
            cs.enforce(
                vec![(cur, Fp::<P, N>::one())],
                vec![(prev, Fp::<P, N>::one())],
                vec![(out, Fp::<P, N>::one())],
            );
            prev = cur;
            cur = out;
        }
    }
    cs
}

/// A square-accumulate circuit (x ← x² + c_i), n constraints — the shape of
/// algebraic-hash chains (MiMC-like rounds, which dominate many real SNARK
/// workloads).
pub fn square_chain<P: FieldParams<N>, const N: usize>(
    n: usize,
    seed: u64,
) -> ConstraintSystem<P, N> {
    let mut rng = Rng::new(seed ^ SQUARE_CHAIN_SEED);
    let mut cs = ConstraintSystem::<P, N>::new();
    let mut x = cs.alloc(Fp::<P, N>::random(&mut rng));
    cs.num_public = 1;
    for _ in 0..n {
        let c = Fp::<P, N>::random(&mut rng);
        let next_val = cs.witness[x].square().add(&c);
        let next = cs.alloc(next_val);
        // x·x = next − c   ⇔   ⟨x⟩·⟨x⟩ = ⟨next − c·1⟩
        cs.enforce(
            vec![(x, Fp::<P, N>::one())],
            vec![(x, Fp::<P, N>::one())],
            vec![(next, Fp::<P, N>::one()), (0, c.neg())],
        );
        x = next;
    }
    cs
}

/// Domain-separation constant for the square-chain generator.
const SQUARE_CHAIN_SEED: u64 = 0x5a5a_1357_9bdf_2468;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::params::{Bls12381FrParams, Bn254FrParams};

    #[test]
    fn mul_chain_satisfied_both_fields() {
        assert!(mul_chain::<Bn254FrParams, 4>(100, 1).is_satisfied());
        assert!(mul_chain::<Bls12381FrParams, 4>(100, 1).is_satisfied());
    }

    #[test]
    fn square_chain_satisfied() {
        let cs = square_chain::<Bn254FrParams, 4>(64, 2);
        assert!(cs.is_satisfied());
        assert_eq!(cs.num_constraints(), 64);
        assert_eq!(cs.num_variables(), 66); // 1 + input + 64 rounds
    }

    #[test]
    fn different_seeds_different_witnesses() {
        let a = mul_chain::<Bn254FrParams, 4>(10, 3);
        let b = mul_chain::<Bn254FrParams, 4>(10, 4);
        assert_ne!(a.witness[1], b.witness[1]);
    }

    #[test]
    fn tampered_chain_fails() {
        let mut cs = mul_chain::<Bn254FrParams, 4>(50, 5);
        let last = cs.witness.len() - 1;
        cs.witness[last] = cs.witness[last].add(&crate::ff::FrBn254::one());
        assert!(!cs.is_satisfied());
    }
}
