//! Transcript-consistency verifier for the synthetic pipeline.
//!
//! A real Groth16 verifier checks one pairing equation against a
//! verifying key distilled from the toxic-waste CRS. This repo's CRS is
//! *synthetic* (deterministic generator multiples, no τ structure — see
//! [`super::setup`]), so a pairing check is not meaningful here and no
//! pairing stack exists. What **can** be checked — and what the
//! soundness tests exercise — is transcript consistency:
//!
//! 1. the claimed public-input count matches the verifying key,
//! 2. every proof element is a valid, non-infinity curve point (a
//!    bit-flipped serialized proof lands off-curve with overwhelming
//!    probability),
//! 3. the proof's public-input commitment π equals the MSM of the
//!    claimed publics over the verifying key's IC basis (the A-query
//!    prefix the prover committed with) — a wrong or reordered public
//!    input cannot reproduce it.
//!
//! This is **not** a cryptographic soundness check: a malicious prover
//! who controls the whole transcript can forge all of it. It is the
//! honest-verifier shape the serving tier and the CLI round-trip
//! through, with the same MSM kernels a real verifier would run.

use super::prover::Proof;
use super::setup::Crs;
use crate::ec::{Affine, CurveParams, Jacobian};
use crate::ff::{Field, FieldParams, Fp};
use crate::msm::{self, Backend, MsmConfig};
use std::fmt;

/// Why a proof was rejected.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VerifyError {
    /// A proof element is off-curve or the point at infinity.
    OffCurve(&'static str),
    /// The verifier was handed the wrong number of public inputs.
    InputCount {
        /// Public inputs the verifying key expects.
        expected: usize,
        /// Public inputs the caller supplied.
        got: usize,
    },
    /// The claimed public inputs do not reproduce the proof's
    /// public-input commitment π over the IC basis.
    PublicInputMismatch,
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VerifyError::OffCurve(el) => write!(f, "proof element {el} is not a valid curve point"),
            VerifyError::InputCount { expected, got } => {
                write!(f, "expected {expected} public inputs, got {got}")
            }
            VerifyError::PublicInputMismatch => {
                write!(f, "public inputs do not match the proof's commitment")
            }
        }
    }
}

impl std::error::Error for VerifyError {}

/// The verifier's half of the transcript: the IC basis (the A-query
/// prefix covering the constant-one wire and the public wires).
pub struct VerifyingKey<G1: CurveParams> {
    /// `ic[0]` pairs with the constant 1, `ic[1..]` with the publics.
    pub ic: Vec<Affine<G1>>,
}

impl<G1: CurveParams> VerifyingKey<G1> {
    /// Distill the verifying key for a circuit with `num_public` public
    /// inputs from the CRS the prover ran with.
    ///
    /// Panics if the CRS is smaller than `1 + num_public` (programmer
    /// error: the CRS could not have covered the circuit either).
    pub fn from_crs<G2: CurveParams>(crs: &Crs<G1, G2>, num_public: usize) -> Self {
        assert!(crs.a_query.len() > num_public, "CRS smaller than the public prefix");
        VerifyingKey { ic: crs.a_query[..1 + num_public].to_vec() }
    }

    /// Public inputs this key expects.
    pub fn num_public(&self) -> usize {
        self.ic.len() - 1
    }
}

/// Check a proof transcript against `public_inputs` (wire order, without
/// the leading constant 1). See the module docs for exactly what this
/// does — and does not — establish.
pub fn verify<G1, G2, P>(
    vk: &VerifyingKey<G1>,
    proof: &Proof<G1, G2>,
    public_inputs: &[Fp<P, 4>],
) -> Result<(), VerifyError>
where
    G1: CurveParams,
    G2: CurveParams,
    P: FieldParams<4>,
{
    if public_inputs.len() != vk.num_public() {
        return Err(VerifyError::InputCount {
            expected: vk.num_public(),
            got: public_inputs.len(),
        });
    }
    check_element(&proof.a, "a")?;
    check_element(&proof.b, "b")?;
    check_element(&proof.c, "c")?;
    check_element(&proof.pi, "pi")?;

    // Recompute the commitment from the claimed publics over the IC
    // basis: [1, publics..] in canonical form, same kernel dispatch as
    // the prover (every backend is bit-identical, so Pippenger is fine).
    let mut scalars = Vec::with_capacity(1 + public_inputs.len());
    scalars.push(Fp::<P, 4>::one().to_canonical());
    scalars.extend(public_inputs.iter().map(Fp::to_canonical));
    let expected = msm::execute(Backend::Pippenger, &vk.ic, &scalars, &MsmConfig::default());
    if !expected.eq_point(&proof.pi) {
        return Err(VerifyError::PublicInputMismatch);
    }
    Ok(())
}

fn check_element<C: CurveParams>(
    p: &Jacobian<C>,
    name: &'static str,
) -> Result<(), VerifyError> {
    if p.is_infinity() || !p.is_on_curve() {
        return Err(VerifyError::OffCurve(name));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{Bn254G1, Bn254G2};
    use crate::ff::params::Bn254FrParams;
    use crate::snark::setup::CrsBn254;
    use crate::snark::{circuits, ConstraintSystem, Prover};
    type Fr = crate::ff::FrBn254;

    fn rig() -> (
        Prover<Bn254G1, Bn254G2, Bn254FrParams>,
        ConstraintSystem<Bn254FrParams, 4>,
        VerifyingKey<Bn254G1>,
        Vec<Fr>,
    ) {
        let cs = circuits::mul_chain::<Bn254FrParams, 4>(120, 5);
        let domain_n = cs.num_constraints().max(2).next_power_of_two();
        let crs = CrsBn254::synthesize(cs.num_variables(), domain_n, 6);
        let vk = VerifyingKey::from_crs(&crs, cs.num_public);
        let publics = cs.witness[1..=cs.num_public].to_vec();
        (Prover::new(crs), cs, vk, publics)
    }

    #[test]
    fn honest_round_trip_verifies() {
        let (prover, cs, vk, publics) = rig();
        let (proof, _) = prover.prove(&cs);
        assert_eq!(verify(&vk, &proof, &publics), Ok(()));
    }

    #[test]
    fn wrong_public_input_rejected() {
        let (prover, cs, vk, mut publics) = rig();
        let (proof, _) = prover.prove(&cs);
        publics[0] = publics[0].add(&Fr::one());
        assert_eq!(verify(&vk, &proof, &publics), Err(VerifyError::PublicInputMismatch));
        // reordering two distinct publics must also fail
        let (prover2, cs2, vk2, mut p2) = rig();
        let (proof2, _) = prover2.prove(&cs2);
        assert_ne!(p2[0], p2[1]);
        p2.swap(0, 1);
        assert_eq!(verify(&vk2, &proof2, &p2), Err(VerifyError::PublicInputMismatch));
    }

    #[test]
    fn input_count_is_checked() {
        let (prover, cs, vk, publics) = rig();
        let (proof, _) = prover.prove(&cs);
        let err = verify(&vk, &proof, &publics[..1]).unwrap_err();
        assert_eq!(err, VerifyError::InputCount { expected: 2, got: 1 });
    }

    #[test]
    fn bit_flipped_elements_rejected() {
        let (prover, cs, vk, publics) = rig();
        let (mut proof, _) = prover.prove(&cs);
        let good_y = proof.a.y;
        proof.a.y = proof.a.y.add(&Field::one());
        assert_eq!(verify(&vk, &proof, &publics), Err(VerifyError::OffCurve("a")));
        proof.a.y = good_y;
        proof.pi = Jacobian::infinity();
        assert_eq!(verify(&vk, &proof, &publics), Err(VerifyError::OffCurve("pi")));
    }

    #[test]
    fn substituted_pi_on_curve_still_mismatches() {
        // an attacker swapping π for a different valid point must hit the
        // commitment check, not the curve check
        let (prover, cs, vk, publics) = rig();
        let (mut proof, _) = prover.prove(&cs);
        proof.pi = proof.pi.add(&Jacobian::generator());
        assert_eq!(verify(&vk, &proof, &publics), Err(VerifyError::PublicInputMismatch));
    }
}
