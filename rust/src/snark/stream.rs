//! Bounded-memory streaming prover: chunked SRS sources + `prove_streaming`.
//!
//! The resident prover materializes five full query vectors (3·nv + h in
//! 𝔾₁, nv in 𝔾₂) before the first MSM — Θ(m) resident bytes, the last
//! in-RAM scalability wall for giant circuits (ROADMAP item 1). This module
//! removes it:
//!
//! * [`StreamingSrs`] — the chunk-source view of `setup::Crs`: either
//!   **generator-backed** (re-derives the exact `Crs::synthesize` point
//!   walks chunk by chunk — nothing is ever materialized) or
//!   **disk-backed** (chunk files written by
//!   [`StreamingSrs::write_to_dir`], which itself streams: setup never
//!   holds more than one chunk).
//! * [`WitnessStream`] — the scalar side: converts resident `Fp` values
//!   (witness assignment, QAP h coefficients) to canonical limbs one
//!   chunk at a time instead of building the full `Vec<ScalarLimbs>`.
//! * [`prove_streaming`] — the same five-MSM pipeline as `Prover::prove`
//!   (identical query slicing: `l_start = 1 + num_public`, h clamped to
//!   the query length), but every MSM runs through
//!   [`msm_stream`](crate::msm::stream::msm_stream) under one enforced
//!   [`MemoryBudget`]. Failures are typed
//!   ([`JobError::StreamFailed`]) — never a wrong proof or partial state —
//!   and retrying with a fresh [`StreamingSrs`] is bit-identical.
//!
//! **Determinism / bit-identity.** Each streamed MSM folds chunk partials
//! in ascending point order (the contiguous special case of
//! `partial::merge`), each chunk runs the same plan machinery as the
//! resident path, and the generator walk emits identical points for any
//! chunking (`ec::points::PointWalk`), so the proof equals the resident
//! `Prover::prove` output projectively (`eq_point`) for every budget that
//! admits at least one element per group. `tests/integration_snark.rs`
//! pins this across curves, budgets and sources.

use std::marker::PhantomData;
use std::path::{Path, PathBuf};
use std::time::Instant;

use super::prover::{Proof, ProverConfig};
use super::qap;
use super::r1cs::ConstraintSystem;
use crate::coordinator::request::JobError;
use crate::ec::{CurveParams, ScalarLimbs};
use crate::ff::{FieldParams, Fp, WordCodec};
use crate::msm::stream::{
    chunk_for_budget, msm_stream, write_points_file, FilePoints, PointStream, ScalarStream,
    StreamError, WalkPoints,
};
use crate::msm::Backend;
use crate::util::mem::{MemLedger, MemoryBudget, SCALAR_BYTES};

const A_FILE: &str = "a_query.pts";
const B1_FILE: &str = "b1_query.pts";
const L_FILE: &str = "l_query.pts";
const B2_FILE: &str = "b2_query.pts";
const H_FILE: &str = "h_query.pts";

/// Where a [`StreamingSrs`] pulls its chunks from.
enum SrsSource {
    /// Re-derive the `Crs::synthesize` walks on the fly.
    Generated { seed: u64 },
    /// Read the chunk files under `dir` (see [`StreamingSrs::write_to_dir`]).
    Disk { dir: PathBuf },
}

/// A chunk-source view of the CRS: same query vectors as
/// `setup::Crs::synthesize`, never fully resident.
pub struct StreamingSrs<G1: CurveParams, G2: CurveParams> {
    source: SrsSource,
    num_vars: usize,
    domain_n: usize,
    _g: PhantomData<(G1, G2)>,
}

/// One query's point source: generator walk or chunk file.
enum SrsStream<C: CurveParams> {
    Walk(WalkPoints<C>),
    File(FilePoints<C>),
}

impl<C: CurveParams> PointStream<C> for SrsStream<C>
where
    C::Base: WordCodec,
{
    fn len(&self) -> usize {
        match self {
            SrsStream::Walk(w) => w.len(),
            SrsStream::File(f) => f.len(),
        }
    }

    fn next_chunk(&mut self, max: usize) -> Result<Vec<crate::ec::Affine<C>>, StreamError> {
        match self {
            SrsStream::Walk(w) => w.next_chunk(max),
            SrsStream::File(f) => f.next_chunk(max),
        }
    }

    fn skip(&mut self, n: usize) -> Result<(), StreamError> {
        match self {
            SrsStream::Walk(w) => PointStream::skip(w, n),
            SrsStream::File(f) => PointStream::skip(f, n),
        }
    }
}

/// Open one query stream over `query[skip..len]`.
fn open_stream<C: CurveParams>(
    source: &SrsSource,
    file: &str,
    seed_xor: u64,
    len: usize,
    skip: usize,
) -> Result<SrsStream<C>, StreamError>
where
    C::Base: WordCodec,
{
    match source {
        SrsSource::Generated { seed } => {
            let mut walk = WalkPoints::<C>::new(seed ^ seed_xor, len);
            PointStream::skip(&mut walk, skip)?;
            Ok(SrsStream::Walk(walk))
        }
        SrsSource::Disk { dir } => {
            let path = dir.join(file);
            let stored = FilePoints::<C>::open(&path)?;
            if PointStream::len(&stored) < len {
                return Err(StreamError::Header {
                    detail: format!("{file}: holds {} points, query needs {len}", stored.len()),
                });
            }
            let mut capped = stored.take(len);
            PointStream::skip(&mut capped, skip)?;
            Ok(SrsStream::File(capped))
        }
    }
}

impl<G1: CurveParams, G2: CurveParams> StreamingSrs<G1, G2> {
    /// Generator-backed source: chunk-identical to
    /// `Crs::synthesize(num_vars, domain_n, seed)` without materializing
    /// any query.
    pub fn generated(num_vars: usize, domain_n: usize, seed: u64) -> Self {
        StreamingSrs {
            source: SrsSource::Generated { seed },
            num_vars,
            domain_n,
            _g: PhantomData,
        }
    }

    /// Disk-backed source over chunk files previously written by
    /// [`Self::write_to_dir`]. Headers are validated lazily at first read.
    pub fn on_disk(dir: &Path, num_vars: usize, domain_n: usize) -> Self {
        StreamingSrs {
            source: SrsSource::Disk { dir: dir.to_path_buf() },
            num_vars,
            domain_n,
            _g: PhantomData,
        }
    }

    /// Variables the per-variable queries (A, B1, L, B2) cover.
    pub fn num_vars(&self) -> usize {
        self.num_vars
    }

    /// QAP domain size the H query derives from.
    pub fn domain_n(&self) -> usize {
        self.domain_n
    }

    /// Length of the H query (`domain_n − 1`, as in `Crs::synthesize`).
    pub fn h_len(&self) -> usize {
        self.domain_n.saturating_sub(1)
    }
}

impl<G1: CurveParams, G2: CurveParams> StreamingSrs<G1, G2>
where
    G1::Base: WordCodec,
    G2::Base: WordCodec,
{
    /// Chunked SRS serialization: stream all five `Crs::synthesize` query
    /// walks for `seed` into chunk files under `dir`, `chunk` points at a
    /// time — setup never holds more than one chunk resident. Returns the
    /// disk-backed source over the written files.
    pub fn write_to_dir(
        dir: &Path,
        num_vars: usize,
        domain_n: usize,
        seed: u64,
        chunk: usize,
    ) -> Result<Self, StreamError> {
        std::fs::create_dir_all(dir)
            .map_err(|e| StreamError::Read { detail: format!("{}: {e}", dir.display()) })?;
        let h_len = domain_n.saturating_sub(1);
        let jobs_g1 = [
            (A_FILE, 0xA1u64, num_vars),
            (B1_FILE, 0xB1, num_vars),
            (L_FILE, 0x11, num_vars),
            (H_FILE, 0x41, h_len),
        ];
        for (file, xor, len) in jobs_g1 {
            let mut walk = WalkPoints::<G1>::new(seed ^ xor, len);
            write_points_file::<G1>(&dir.join(file), &mut walk, chunk)?;
        }
        let mut walk = WalkPoints::<G2>::new(seed ^ 0xB2, num_vars);
        write_points_file::<G2>(&dir.join(B2_FILE), &mut walk, chunk)?;
        Ok(Self::on_disk(dir, num_vars, domain_n))
    }

    // The per-variable streams open at the *caller's* `len` (the witness
    // size), mirroring the resident prover's `query[..nv]` slicing — the
    // stored/generated query may be larger than the circuit needs.
    fn a_stream(&self, len: usize) -> Result<SrsStream<G1>, StreamError> {
        open_stream::<G1>(&self.source, A_FILE, 0xA1, len, 0)
    }

    fn b1_stream(&self, len: usize) -> Result<SrsStream<G1>, StreamError> {
        open_stream::<G1>(&self.source, B1_FILE, 0xB1, len, 0)
    }

    fn l_stream(&self, len: usize, skip: usize) -> Result<SrsStream<G1>, StreamError> {
        open_stream::<G1>(&self.source, L_FILE, 0x11, len, skip)
    }

    fn h_stream(&self, len: usize) -> Result<SrsStream<G1>, StreamError> {
        open_stream::<G1>(&self.source, H_FILE, 0x41, len, 0)
    }

    fn b2_stream(&self, len: usize) -> Result<SrsStream<G2>, StreamError> {
        open_stream::<G2>(&self.source, B2_FILE, 0xB2, len, 0)
    }
}

/// Chunked canonical-limb view of resident `Fp` values (the witness
/// assignment, the QAP h coefficients): the conversion the resident
/// prover does in one Θ(m) pass happens here one chunk at a time.
pub struct WitnessStream<'a, P: FieldParams<4>> {
    values: &'a [Fp<P, 4>],
    cursor: usize,
}

impl<'a, P: FieldParams<4>> WitnessStream<'a, P> {
    /// Stream `values`, front to back.
    pub fn new(values: &'a [Fp<P, 4>]) -> Self {
        WitnessStream { values, cursor: 0 }
    }
}

impl<P: FieldParams<4>> ScalarStream for WitnessStream<'_, P> {
    fn len(&self) -> usize {
        self.values.len() - self.cursor
    }

    fn next_chunk(&mut self, max: usize) -> Result<Vec<ScalarLimbs>, StreamError> {
        let take = max.min(self.len());
        let out = self.values[self.cursor..self.cursor + take]
            .iter()
            .map(Fp::to_canonical)
            .collect();
        self.cursor += take;
        Ok(out)
    }
}

/// What the streaming prover observed: the accounted memory envelope and
/// the chunk geometry (the numbers `BENCH_memory.json` records and
/// `tests/perf_smoke.rs` pins).
#[derive(Clone, Debug)]
pub struct StreamReport {
    /// High-water mark of the chunk lane — never exceeds the budget.
    pub peak_chunk_bytes: u64,
    /// Θ(m) resident scalar inputs (witness + h coefficients), tracked
    /// on the uncapped fixed lane.
    pub fixed_bytes: u64,
    /// The enforced budget, in bytes.
    pub budget_bytes: u64,
    /// Points per 𝔾₁ chunk the budget admits.
    pub chunk_points_g1: usize,
    /// Points per 𝔾₂ chunk the budget admits.
    pub chunk_points_g2: usize,
    /// Wall seconds of the whole streaming prove.
    pub total_s: f64,
}

/// Run the five-MSM prover pipeline against a [`StreamingSrs`] in bounded
/// memory: every query MSM streams through chunk sources under `budget`
/// (enforced per chunk by a shared [`MemLedger`]). The proof is
/// bit-identical (`eq_point`) to `Prover::prove` over the equivalent
/// resident CRS. Uses `cfg`'s MSM plan, backend selection and NTT thread
/// budget; `cfg.point_cache` and `cfg.pools` do not apply to the streaming
/// path (both presume a resident point set) and are ignored.
///
/// Errors are typed: a failing or short chunk source, a malformed chunk
/// file, or a budget that cannot hold one element all surface as
/// [`JobError::StreamFailed`] — never a wrong proof, hang, or partially
/// accounted ledger.
pub fn prove_streaming<G1, G2, P>(
    cs: &ConstraintSystem<P, 4>,
    srs: &StreamingSrs<G1, G2>,
    budget: MemoryBudget,
    cfg: &ProverConfig<G1, G2>,
) -> Result<(Proof<G1, G2>, StreamReport), JobError>
where
    G1: CurveParams,
    G2: CurveParams,
    P: FieldParams<4>,
    G1::Base: WordCodec,
    G2::Base: WordCodec,
{
    let start = Instant::now();
    let chunk_g1 = chunk_for_budget::<G1>(budget.get());
    let chunk_g2 = chunk_for_budget::<G2>(budget.get());
    if chunk_g1 == 0 || chunk_g2 == 0 {
        let needed = G1::AFFINE_BYTES.max(G2::AFFINE_BYTES) + SCALAR_BYTES;
        return Err(StreamError::BudgetTooSmall { needed, budget: budget.get() }.into());
    }
    let nv = cs.num_variables();
    if srs.num_vars() < nv {
        return Err(JobError::StreamFailed(format!(
            "SRS smaller than witness: {} vars vs {nv}",
            srs.num_vars()
        )));
    }

    // Same front half as the resident prover: witness evaluation + QAP.
    let (a_evals, b_evals, c_evals) = cs.constraint_evals();
    let (qapw, _ntt_phases) = qap::compute_h_with(&a_evals, &b_evals, &c_evals, cfg.ntt_threads)
        .expect("domain within field 2-adicity");

    let l_start = 1 + cs.num_public;
    let h_len = qapw.h_coeffs.len().min(srs.h_len());

    let ledger = MemLedger::new(budget);
    // The Θ(m) inputs the streaming path still holds resident: the witness
    // assignment and the QAP h coefficients (32 canonical bytes each).
    ledger.note_fixed((cs.witness.len() + qapw.h_coeffs.len()) as u64 * SCALAR_BYTES);

    let g1_backend = if cfg.auto_backend {
        Backend::auto_for::<G1>(chunk_g1.min(nv), &cfg.msm)
    } else {
        cfg.backend
    };
    let g2_backend = if cfg.auto_backend {
        Backend::auto_for::<G2>(chunk_g2.min(nv), &cfg.msm)
    } else {
        cfg.backend
    };

    let a_msm = msm_stream(
        &mut srs.a_stream(nv)?,
        &mut WitnessStream::new(&cs.witness),
        g1_backend,
        &cfg.msm,
        chunk_g1,
        &ledger,
    )?;
    let _b1_msm = msm_stream(
        &mut srs.b1_stream(nv)?,
        &mut WitnessStream::new(&cs.witness),
        g1_backend,
        &cfg.msm,
        chunk_g1,
        &ledger,
    )?;
    let l_msm = msm_stream(
        &mut srs.l_stream(nv, l_start)?,
        &mut WitnessStream::new(&cs.witness[l_start..]),
        g1_backend,
        &cfg.msm,
        chunk_g1,
        &ledger,
    )?;
    let h_msm = msm_stream(
        &mut srs.h_stream(h_len)?,
        &mut WitnessStream::new(&qapw.h_coeffs[..h_len]),
        g1_backend,
        &cfg.msm,
        chunk_g1,
        &ledger,
    )?;
    let b2_msm = msm_stream(
        &mut srs.b2_stream(nv)?,
        &mut WitnessStream::new(&cs.witness),
        g2_backend,
        &cfg.msm,
        chunk_g2,
        &ledger,
    )?;

    // π (public-input commitment): the A-query prefix over [1, publics..].
    // Streams through the same chunk lane and ledger as the query MSMs —
    // its (tiny, ≤ one chunk) charge is released before the report reads
    // the high-water mark, so the pinned peak/fixed accounting is
    // unchanged. Bit-identical to the resident prover's π.
    let pi = msm_stream(
        &mut srs.a_stream(l_start)?,
        &mut WitnessStream::new(&cs.witness[..l_start]),
        g1_backend,
        &cfg.msm,
        chunk_g1,
        &ledger,
    )?;

    let proof = Proof { a: a_msm, b: b2_msm, c: l_msm.add(&h_msm), pi };
    let report = StreamReport {
        peak_chunk_bytes: ledger.peak_bytes(),
        fixed_bytes: ledger.fixed_bytes(),
        budget_bytes: budget.get(),
        chunk_points_g1: chunk_g1,
        chunk_points_g2: chunk_g2,
        total_s: start.elapsed().as_secs_f64(),
    };
    Ok((proof, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{Bn254G1, Bn254G2};
    use crate::ff::params::Bn254FrParams;
    use crate::snark::setup::CrsBn254;
    use crate::snark::{circuits, Prover};

    fn cs_and_resident_proof() -> (
        ConstraintSystem<Bn254FrParams, 4>,
        Proof<Bn254G1, Bn254G2>,
        usize,
        usize,
    ) {
        let cs = circuits::mul_chain::<Bn254FrParams, 4>(150, 77);
        let domain_n = (cs.num_constraints().max(2)).next_power_of_two();
        let nv = cs.num_variables();
        let crs = CrsBn254::synthesize(nv, domain_n, 9);
        let prover = Prover::<_, _, Bn254FrParams>::new(crs);
        let (proof, _) = prover.prove(&cs);
        (cs, proof, nv, domain_n)
    }

    #[test]
    fn generated_streaming_matches_resident_prover() {
        let (cs, want, nv, domain_n) = cs_and_resident_proof();
        let srs = StreamingSrs::<Bn254G1, Bn254G2>::generated(nv, domain_n, 9);
        // a budget admitting ~16 G2 points per chunk — far below Θ(m)
        let budget = MemoryBudget::bytes(16 * (Bn254G2::AFFINE_BYTES + SCALAR_BYTES));
        let (got, report) =
            prove_streaming(&cs, &srs, budget, &ProverConfig::default()).unwrap();
        assert!(got.a.eq_point(&want.a));
        assert!(got.b.eq_point(&want.b));
        assert!(got.c.eq_point(&want.c));
        assert!(got.pi.eq_point(&want.pi));
        assert!(report.peak_chunk_bytes <= report.budget_bytes);
        assert_eq!(report.chunk_points_g2, 16);
    }

    #[test]
    fn disk_streaming_matches_resident_prover() {
        let (cs, want, nv, domain_n) = cs_and_resident_proof();
        let dir = std::env::temp_dir().join("ifzkp_srs_unit");
        let srs =
            StreamingSrs::<Bn254G1, Bn254G2>::write_to_dir(&dir, nv, domain_n, 9, 37).unwrap();
        let budget = MemoryBudget::mib(1);
        let (got, _) = prove_streaming(&cs, &srs, budget, &ProverConfig::default()).unwrap();
        assert!(got.a.eq_point(&want.a));
        assert!(got.b.eq_point(&want.b));
        assert!(got.c.eq_point(&want.c));
        assert!(got.pi.eq_point(&want.pi));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn budget_too_small_is_typed() {
        let (cs, _, nv, domain_n) = cs_and_resident_proof();
        let srs = StreamingSrs::<Bn254G1, Bn254G2>::generated(nv, domain_n, 9);
        // cannot hold one G2 element (needs 160 bytes on BN254)
        let err = prove_streaming(
            &cs,
            &srs,
            MemoryBudget::bytes(100),
            &ProverConfig::default(),
        )
        .unwrap_err();
        assert!(matches!(err, JobError::StreamFailed(_)), "{err:?}");
    }

    #[test]
    fn undersized_srs_is_typed() {
        let (cs, _, nv, domain_n) = cs_and_resident_proof();
        let srs = StreamingSrs::<Bn254G1, Bn254G2>::generated(nv - 1, domain_n, 9);
        let err = prove_streaming(
            &cs,
            &srs,
            MemoryBudget::mib(1),
            &ProverConfig::default(),
        )
        .unwrap_err();
        assert!(matches!(err, JobError::StreamFailed(_)), "{err:?}");
    }

    #[test]
    fn witness_stream_chunks_match_one_shot_conversion() {
        let cs = circuits::mul_chain::<Bn254FrParams, 4>(40, 3);
        let want: Vec<ScalarLimbs> = cs.witness.iter().map(Fp::to_canonical).collect();
        let mut ws = WitnessStream::new(&cs.witness);
        let mut got = Vec::new();
        while !ws.is_empty() {
            got.extend(ws.next_chunk(7).unwrap());
        }
        assert_eq!(got, want);
    }
}
