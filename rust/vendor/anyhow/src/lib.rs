//! Minimal, offline-compatible subset of the `anyhow` API.
//!
//! The real crate is not vendorable in this environment (no network at
//! build time), and the repo only uses a narrow slice of it: [`Error`],
//! [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros and the
//! [`Context`] extension trait. Errors are stored as flattened message
//! strings; `{}`, `{:#}`, and `{:?}` all render the full context chain,
//! matching how the host crate formats them.

use std::fmt;

/// A flattened error: the accumulated context chain as one message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// The full message (context chain included).
    pub fn to_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion legal.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// `anyhow::Result`, defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a fallible value (subset of anyhow's trait).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an error when a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(anyhow!("bad {}", 7))
    }

    #[test]
    fn macro_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "bad 7");
        assert_eq!(format!("{e:#}"), "bad 7");
        assert_eq!(format!("{e:?}"), "bad 7");
    }

    #[test]
    fn context_chains() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "io boom"));
        let e = r.context("opening artifact").unwrap_err();
        assert!(format!("{e}").contains("opening artifact"));
        assert!(format!("{e}").contains("io boom"));
        let n: Option<u32> = None;
        assert!(n.context("missing").is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert!(f(3).is_ok());
        assert!(f(5).is_err());
        assert!(f(11).unwrap_err().to_string().contains("too big"));
    }
}
