//! API-compatible **stub** of the `xla` PJRT bindings.
//!
//! The real bindings wrap a C++ PJRT runtime that cannot be built in this
//! offline environment. This stub exposes the exact API surface the host
//! crate compiles against; every entry point that would touch a device
//! returns an error, and [`available()`] reports `false` so callers and
//! tests can skip engine paths gracefully. The host's engine code paths
//! (`runtime::context` / `runtime::engine`) degrade to `Err` at runtime and
//! the native MSM backends carry the work instead.

use std::fmt;

/// Whether a real PJRT backend is linked in. Always `false` for the stub.
pub fn available() -> bool {
    false
}

const STUB_MSG: &str = "PJRT unavailable: offline xla stub (see rust/vendor/xla)";

/// Stub error (implements `std::error::Error` so `?` conversions work).
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn stub() -> Error {
        Error(STUB_MSG.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub())
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable (stub: never constructible through public APIs,
/// but the type must exist for signatures).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub())
    }
}

/// A device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub())
    }
}

/// A host-side literal value.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        Err(Error::stub())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(!available());
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
