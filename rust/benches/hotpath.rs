//! Hot-path microbenchmarks (the §Perf instrumentation): field mul, EC
//! point ops, MSM per-point cost, NTT butterflies — ns/op so the perf pass
//! can track improvements without criterion.

use ifzkp::ec::{points, Bls12381G1, Bn254G1, CurveParams, Jacobian};
use ifzkp::ff::{Field, FpBls12381, FpBn254, FrBn254};
use ifzkp::msm::{self, pippenger, MsmConfig, MsmPlan, Reduction, Slicing};
use ifzkp::ntt;
use ifzkp::util::rng::Rng;
use ifzkp::util::Stopwatch;

fn bench(name: &str, iters: u64, mut f: impl FnMut()) {
    for _ in 0..iters / 10 + 1 {
        f(); // warmup
    }
    let sw = Stopwatch::start();
    for _ in 0..iters {
        f();
    }
    let total = sw.secs();
    println!("{name:<44} {:>12.1} ns/op   ({iters} iters)", total * 1e9 / iters as f64);
}

fn bench_field<F: Field>(label: &str, iters: u64) {
    let mut rng = Rng::new(1);
    let a = F::random(&mut rng);
    let b = F::random(&mut rng);
    let mut acc = a;
    bench(&format!("{label} mul"), iters, || {
        acc = acc.mul(&b);
    });
    bench(&format!("{label} square"), iters, || {
        acc = acc.square();
    });
    bench(&format!("{label} add"), iters, || {
        acc = acc.add(&b);
    });
    let mut inv_in = a;
    bench(&format!("{label} inverse"), iters / 100 + 1, || {
        inv_in = inv_in.inv().unwrap();
    });
    std::hint::black_box(acc);
}

fn bench_curve<C: CurveParams>(label: &str, iters: u64) {
    let pts = points::generate_points_walk::<C>(4, 2);
    let mut p = pts[0].to_jacobian();
    let q = pts[1].to_jacobian();
    let qa = pts[2];
    bench(&format!("{label} jacobian add"), iters, || {
        p = p.add(&q);
    });
    bench(&format!("{label} mixed add"), iters, || {
        p = p.add_mixed(&qa);
    });
    bench(&format!("{label} double"), iters, || {
        p = p.double();
    });
    std::hint::black_box(&p);
}

fn main() {
    println!("== hot-path microbenchmarks ==");
    bench_field::<FpBn254>("Fp(BN254, 4x64)", 200_000);
    bench_field::<FpBls12381>("Fp(BLS12-381, 6x64)", 100_000);
    bench_field::<ifzkp::ff::Fp2Bn254>("Fp2(BN254)", 50_000);

    bench_curve::<Bn254G1>("BN254 G1", 20_000);
    bench_curve::<Bls12381G1>("BLS12-381 G1", 10_000);

    // MSM per-point cost at a realistic size
    for (label, red) in
        [("running-sum", Reduction::RunningSum), ("IS-RBAM k2=6", Reduction::Recursive { k2: 6 })]
    {
        let m = 1 << 14;
        let w = points::workload::<Bn254G1>(m, 3);
        let cfg = MsmConfig::new(12, red);
        let sw = Stopwatch::start();
        let out = msm::msm_pippenger(&w.points, &w.scalars, &cfg);
        let t = sw.secs();
        std::hint::black_box(out);
        println!(
            "BN254 MSM 2^14 ({label:<13})              {:>12.1} ns/point  ({:.3}s total)",
            t * 1e9 / m as f64,
            t
        );
    }

    // signed vs unsigned buckets at equal k: the reduce-phase serial chain
    // (the quantity the hardware pays 270-cycle latency per op for) halves
    let mut signed_cmp: Vec<(Slicing, Jacobian<Bn254G1>, u64, u64, f64)> = Vec::new();
    for slicing in [Slicing::Unsigned, Slicing::Signed] {
        let m = 1 << 14;
        let w = points::workload::<Bn254G1>(m, 3);
        let cfg = MsmConfig { window_bits: 12, reduction: Reduction::RunningSum, slicing };
        let plan = MsmPlan::for_curve::<Bn254G1>(&cfg);
        let sw = Stopwatch::start();
        let (out, cost) = pippenger::msm_with_cost(&w.points, &w.scalars, &cfg);
        let t = sw.secs();
        println!(
            "BN254 MSM 2^14 ({:<9} k=12, run-sum)       {:>12.1} ns/point  (serial reduce ops: {} plan / {} measured)",
            format!("{slicing:?}"),
            t * 1e9 / m as f64,
            plan.serial_reduce_ops(),
            cost.reduce_ops,
        );
        signed_cmp.push((slicing, out, plan.serial_reduce_ops(), cost.reduce_ops, t));
    }
    assert!(signed_cmp[0].1.eq_point(&signed_cmp[1].1), "signed != unsigned result");
    println!(
        "  signed-digit serial-chain reduction:        {:.2}x (plan), {:.2}x (measured)",
        signed_cmp[0].2 as f64 / signed_cmp[1].2 as f64,
        signed_cmp[0].3 as f64 / signed_cmp[1].3 as f64,
    );

    // batch-affine fills (the §Perf/L3 optimization) vs Jacobian fills
    for (label, k) in [("k=8 fill-heavy", 8u32), ("k=12 hw window", 12)] {
        let m = 1 << 14;
        let w = points::workload::<Bn254G1>(m, 3);
        let cfg = MsmConfig::new(k, Reduction::Recursive { k2: 6 });
        let sw = Stopwatch::start();
        let jac = msm::msm_pippenger(&w.points, &w.scalars, &cfg);
        let t_jac = sw.secs();
        let sw = Stopwatch::start();
        let aff = msm::batch_affine::msm(&w.points, &w.scalars, &cfg);
        let t_aff = sw.secs();
        assert!(jac.eq_point(&aff));
        println!(
            "BN254 MSM 2^14 batch-affine ({label})      {:>12.1} ns/point (vs jacobian {:.1}; {:.2}x)",
            t_aff * 1e9 / m as f64,
            t_jac * 1e9 / m as f64,
            t_jac / t_aff
        );
    }

    // parallel scaling
    for threads in [1usize, 2, 4] {
        let m = 1 << 14;
        let w = points::workload::<Bn254G1>(m, 3);
        let cfg = MsmConfig::default();
        let sw = Stopwatch::start();
        let out = msm::parallel::msm(&w.points, &w.scalars, &cfg, threads);
        let t = sw.secs();
        std::hint::black_box(out);
        println!(
            "BN254 MSM 2^14 parallel x{threads}                  {:>12.1} ns/point",
            t * 1e9 / m as f64
        );
    }

    // NTT
    let mut rng = Rng::new(4);
    let dom = ntt::domain::Domain::<ifzkp::ff::params::Bn254FrParams, 4>::new(1 << 14).unwrap();
    let mut v: Vec<FrBn254> = (0..1 << 14).map(|_| FrBn254::random(&mut rng)).collect();
    let sw = Stopwatch::start();
    let reps = 10;
    for _ in 0..reps {
        ntt::ntt_in_place(&mut v, &dom.omega);
    }
    let t = sw.secs() / reps as f64;
    println!(
        "NTT 2^14 (BN254 Fr)                          {:>12.1} ns/element  ({:.1}ms per transform)",
        t * 1e9 / (1 << 14) as f64,
        t * 1e3
    );

    // engine (if artifacts present): batched UDA throughput
    let dir = ifzkp::runtime::artifact::default_dir();
    if dir.join("manifest.json").exists() && std::env::var("IFZKP_BENCH_ENGINE").is_ok() {
        println!("\n== PJRT UDA engine ==");
        let ctx = ifzkp::runtime::PjrtContext::cpu().unwrap();
        let manifest = ifzkp::runtime::ArtifactManifest::load(&dir).unwrap();
        let sw = Stopwatch::start();
        let engine = ifzkp::runtime::UdaEngine::<Bn254G1>::load(&ctx, &manifest).unwrap();
        println!("artifact compile: {:.1}s", sw.secs());
        let b = engine.batch();
        let pts = points::generate_points_walk::<Bn254G1>(2 * b, 5);
        let pairs: Vec<(Jacobian<Bn254G1>, Jacobian<Bn254G1>)> =
            (0..b).map(|i| (pts[i].to_jacobian(), pts[i + b].to_jacobian())).collect();
        let _ = engine.uda_batch(&pairs).unwrap(); // warm
        let sw = Stopwatch::start();
        let reps = 20;
        for _ in 0..reps {
            let _ = engine.uda_batch(&pairs).unwrap();
        }
        let t = sw.secs() / reps as f64;
        println!(
            "engine UDA batch={b}: {:.2} ms/batch = {:.1} us/point-op",
            t * 1e3,
            t * 1e6 / b as f64
        );
    } else {
        println!("\n(engine bench skipped: set IFZKP_BENCH_ENGINE=1 with artifacts built)");
    }
}
