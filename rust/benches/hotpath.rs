//! Hot-path microbenchmarks (the §Perf instrumentation): field mul, EC
//! point ops, MSM per-point cost, the chunk-parallel runtime's
//! recode/fill/merge/reduce phase split, sharded multi-device MSM, and
//! the NTT runtime (serial reference vs cached-plan serial/parallel/
//! four-step at 2^16, plus the prover-shaped per-phase split) — ns/op so
//! the perf pass can track improvements without criterion. The JSON
//! artifact schema is documented in the repo-root `BENCHMARKS.md`.
//!
//! CI knobs:
//! * `IFZKP_BENCH_QUICK=1` — small-n smoke (seconds, not minutes);
//! * `IFZKP_BENCH_JSON=path` — also write the results as a flat JSON
//!   array (`BENCH_hotpath.json` in CI, uploaded as an artifact so the
//!   perf trajectory accumulates run over run).

use ifzkp::coordinator::shard::ShardPool;
use ifzkp::ec::{points, Bls12381G1, Bn254G1, CurveParams, Jacobian};
use ifzkp::ff::{Field, FieldParams, Fp, FpBls12381, FpBn254, FpLanes, FrBn254, LANES};
use ifzkp::msm::{self, pippenger, MsmConfig, MsmPlan, Reduction, ShardPolicy, Slicing};
use ifzkp::ntt;
use ifzkp::util::json::Json;
use ifzkp::util::rng::Rng;
use ifzkp::util::Stopwatch;

/// Collected (name, ns/op) pairs for the JSON artifact.
struct Results {
    entries: Vec<(String, f64)>,
}

impl Results {
    fn record(&mut self, name: &str, ns_per_op: f64) {
        self.entries.push((name.to_string(), ns_per_op));
    }

    fn emit_json(&self) {
        let Ok(path) = std::env::var("IFZKP_BENCH_JSON") else {
            return;
        };
        let mut arr = Vec::with_capacity(self.entries.len());
        for (name, ns) in &self.entries {
            let mut j = Json::obj();
            j.set("name", name.as_str()).set("ns_per_op", *ns);
            arr.push(j);
        }
        let mut root = Json::obj();
        root.set("bench", "hotpath").set("results", Json::Arr(arr));
        match std::fs::write(&path, format!("{root}\n")) {
            Ok(()) => println!("\nwrote bench JSON: {path}"),
            Err(e) => eprintln!("\nfailed to write bench JSON {path}: {e}"),
        }
    }
}

fn bench(results: &mut Results, name: &str, iters: u64, mut f: impl FnMut()) {
    for _ in 0..iters / 10 + 1 {
        f(); // warmup
    }
    let sw = Stopwatch::start();
    for _ in 0..iters {
        f();
    }
    let total = sw.secs();
    let ns = total * 1e9 / iters as f64;
    println!("{name:<44} {ns:>12.1} ns/op   ({iters} iters)");
    results.record(name, ns);
}

fn bench_field<F: Field>(results: &mut Results, label: &str, iters: u64) {
    let mut rng = Rng::new(1);
    let a = F::random(&mut rng);
    let b = F::random(&mut rng);
    let mut acc = a;
    bench(results, &format!("{label} mul"), iters, || {
        acc = acc.mul(&b);
    });
    bench(results, &format!("{label} square"), iters, || {
        acc = acc.square();
    });
    bench(results, &format!("{label} add"), iters, || {
        acc = acc.add(&b);
    });
    let mut inv_in = a;
    bench(results, &format!("{label} inverse"), iters / 100 + 1, || {
        inv_in = inv_in.inv().unwrap();
    });
    std::hint::black_box(acc);
}

/// The `ff` section's core entries: four chained scalar ops against one
/// chained lane op, same op count, with bit-identity asserted across the
/// whole timed chain (warmup + timed iterations run the same schedule on
/// both sides).
fn bench_lanes<P: FieldParams<N>, const N: usize>(results: &mut Results, label: &str, iters: u64) {
    let mut rng = Rng::new(6);
    let a: [Fp<P, N>; LANES] = std::array::from_fn(|_| Fp::random(&mut rng));
    let b: [Fp<P, N>; LANES] = std::array::from_fn(|_| Fp::random(&mut rng));
    let mut sa = a;
    bench(results, &format!("ff {label} scalar mul x4"), iters, || {
        for l in 0..LANES {
            sa[l] = sa[l].mul(&b[l]);
        }
    });
    let mut la = FpLanes::from_elems(&a);
    let lb = FpLanes::from_elems(&b);
    bench(results, &format!("ff {label} lane mul4"), iters, || {
        la = la.mul4(&lb);
    });
    assert_eq!(la.to_elems(), sa, "{label}: lane/scalar mul chains diverged");
    let mut sq = a;
    bench(results, &format!("ff {label} scalar square x4"), iters, || {
        for l in 0..LANES {
            sq[l] = sq[l].square();
        }
    });
    let mut lq = FpLanes::from_elems(&a);
    bench(results, &format!("ff {label} lane square4"), iters, || {
        lq = lq.square4();
    });
    assert_eq!(lq.to_elems(), sq, "{label}: lane/scalar square chains diverged");
    std::hint::black_box((&sa, &sq));
}

fn bench_curve<C: CurveParams>(results: &mut Results, label: &str, iters: u64) {
    let pts = points::generate_points_walk::<C>(4, 2);
    let mut p = pts[0].to_jacobian();
    let q = pts[1].to_jacobian();
    let qa = pts[2];
    bench(results, &format!("{label} jacobian add"), iters, || {
        p = p.add(&q);
    });
    bench(results, &format!("{label} mixed add"), iters, || {
        p = p.add_mixed(&qa);
    });
    bench(results, &format!("{label} double"), iters, || {
        p = p.double();
    });
    std::hint::black_box(&p);
}

fn main() {
    let quick = std::env::var("IFZKP_BENCH_QUICK").is_ok();
    let scale = if quick { 50 } else { 1 };
    let msm_m: usize = if quick { 1 << 10 } else { 1 << 14 };
    let msm_label = if quick { "2^10" } else { "2^14" };
    let mut results = Results { entries: Vec::new() };
    println!("== hot-path microbenchmarks{} ==", if quick { " (quick)" } else { "" });
    bench_field::<FpBn254>(&mut results, "Fp(BN254, 4x64)", 200_000 / scale);
    bench_field::<FpBls12381>(&mut results, "Fp(BLS12-381, 6x64)", 100_000 / scale);
    bench_field::<ifzkp::ff::Fp2Bn254>(&mut results, "Fp2(BN254)", 50_000 / scale);

    // the vectorized field core: one 4-lane op vs four scalar ops
    bench_lanes::<ifzkp::ff::params::Bn254FpParams, 4>(&mut results, "Fp(BN254)", 50_000 / scale);
    bench_lanes::<ifzkp::ff::params::Bls12381FpParams, 6>(
        &mut results,
        "Fp(BLS12-381)",
        25_000 / scale,
    );

    bench_curve::<Bn254G1>(&mut results, "BN254 G1", 20_000 / scale);
    bench_curve::<Bls12381G1>(&mut results, "BLS12-381 G1", 10_000 / scale);

    // MSM per-point cost at a realistic size
    for (label, red) in
        [("running-sum", Reduction::RunningSum), ("IS-RBAM k2=6", Reduction::Recursive { k2: 6 })]
    {
        let w = points::workload::<Bn254G1>(msm_m, 3);
        let cfg = MsmConfig::new(12, red);
        let sw = Stopwatch::start();
        let out = msm::msm_pippenger(&w.points, &w.scalars, &cfg);
        let t = sw.secs();
        std::hint::black_box(out);
        let ns = t * 1e9 / msm_m as f64;
        println!("BN254 MSM {msm_label} ({label:<13})              {ns:>12.1} ns/point  ({t:.3}s total)");
        results.record(&format!("BN254 MSM {msm_label} {label} ns/point"), ns);
    }

    // signed vs unsigned buckets at equal k: the reduce-phase serial chain
    // (the quantity the hardware pays 270-cycle latency per op for) halves
    let mut signed_cmp: Vec<(Slicing, Jacobian<Bn254G1>, u64, u64, f64)> = Vec::new();
    for slicing in [Slicing::Unsigned, Slicing::Signed] {
        let w = points::workload::<Bn254G1>(msm_m, 3);
        let cfg = MsmConfig {
            window_bits: 12,
            reduction: Reduction::RunningSum,
            slicing,
            ..Default::default()
        };
        let plan = MsmPlan::for_curve::<Bn254G1>(&cfg);
        let sw = Stopwatch::start();
        let (out, cost) = pippenger::msm_with_cost(&w.points, &w.scalars, &cfg);
        let t = sw.secs();
        println!(
            "BN254 MSM {msm_label} ({:<9} k=12, run-sum)       {:>12.1} ns/point  (serial reduce ops: {} plan / {} measured)",
            format!("{slicing:?}"),
            t * 1e9 / msm_m as f64,
            plan.serial_reduce_ops(),
            cost.reduce_ops,
        );
        results
            .record(&format!("BN254 MSM {msm_label} {slicing:?} run-sum ns/point"), t * 1e9 / msm_m as f64);
        signed_cmp.push((slicing, out, plan.serial_reduce_ops(), cost.reduce_ops, t));
    }
    assert!(signed_cmp[0].1.eq_point(&signed_cmp[1].1), "signed != unsigned result");
    println!(
        "  signed-digit serial-chain reduction:        {:.2}x (plan), {:.2}x (measured)",
        signed_cmp[0].2 as f64 / signed_cmp[1].2 as f64,
        signed_cmp[0].3 as f64 / signed_cmp[1].3 as f64,
    );

    // GLV endomorphism split vs full-width scalars (both k=12, IS-RBAM):
    // half the window passes against the doubled (P, phi(P)) set — total
    // fills unchanged, the serial reduce chain and combine halve again
    {
        let w = points::workload::<Bn254G1>(msm_m, 3);
        let cfg = MsmConfig::new(12, Reduction::Recursive { k2: 6 });
        let sw = Stopwatch::start();
        let full = msm::msm_pippenger(&w.points, &w.scalars, &cfg);
        let t_full = sw.secs();
        let glv_cfg = cfg.glv();
        let sw = Stopwatch::start();
        let glv = msm::msm_pippenger(&w.points, &w.scalars, &glv_cfg);
        let t_glv = sw.secs();
        assert!(glv.eq_point(&full), "GLV result != full-width result");
        let pf = MsmPlan::for_curve::<Bn254G1>(&cfg);
        let pg = MsmPlan::for_curve::<Bn254G1>(&glv_cfg);
        println!(
            "BN254 MSM {msm_label} GLV (k=12, IS-RBAM)           {:>12.1} ns/point  (vs full {:.1}; {:.2}x; windows {} -> {}, serial chain {} -> {})",
            t_glv * 1e9 / msm_m as f64,
            t_full * 1e9 / msm_m as f64,
            t_full / t_glv,
            pf.windows,
            pg.windows,
            pf.serial_reduce_ops(),
            pg.serial_reduce_ops(),
        );
        results.record(&format!("BN254 MSM {msm_label} glv ns/point"), t_glv * 1e9 / msm_m as f64);
    }

    // batch-affine fills (the §Perf/L3 optimization) vs Jacobian fills
    for (label, k) in [("k=8 fill-heavy", 8u32), ("k=12 hw window", 12)] {
        let w = points::workload::<Bn254G1>(msm_m, 3);
        let cfg = MsmConfig::new(k, Reduction::Recursive { k2: 6 });
        let sw = Stopwatch::start();
        let jac = msm::msm_pippenger(&w.points, &w.scalars, &cfg);
        let t_jac = sw.secs();
        let sw = Stopwatch::start();
        let aff = msm::batch_affine::msm(&w.points, &w.scalars, &cfg);
        let t_aff = sw.secs();
        assert!(jac.eq_point(&aff));
        println!(
            "BN254 MSM {msm_label} batch-affine ({label})      {:>12.1} ns/point (vs jacobian {:.1}; {:.2}x)",
            t_aff * 1e9 / msm_m as f64,
            t_jac * 1e9 / msm_m as f64,
            t_jac / t_aff
        );
        results.record(
            &format!("BN254 MSM {msm_label} batch-affine {label} ns/point"),
            t_aff * 1e9 / msm_m as f64,
        );
    }

    // lane-fed 2^16 end-to-end deltas (the ff section's acceptance
    // points): the batch-affine fill and the planned serial NTT both run
    // their field inner loops through the 4-lane core now, so these two
    // entries track what the lane core buys end to end. Like the other
    // 2^16 sections, deliberately NOT scaled by IFZKP_BENCH_QUICK — the
    // deltas only mean something at the acceptance size, and both are
    // bounded at seconds.
    {
        let m: usize = 1 << 16;
        let w = points::workload::<Bn254G1>(m, 3);
        let cfg = MsmConfig::new(12, Reduction::Recursive { k2: 6 }).glv();
        let sw = Stopwatch::start();
        let jac = msm::msm_pippenger(&w.points, &w.scalars, &cfg);
        let t_jac = sw.secs();
        let sw = Stopwatch::start();
        let aff = msm::batch_affine::msm(&w.points, &w.scalars, &cfg);
        let t_aff = sw.secs();
        assert!(aff.eq_point(&jac), "lane-fed batch-affine diverged at 2^16");
        println!(
            "ff 2^16 MSM lane batch-affine fill           {:>10.1} ns/point  (vs jacobian {:.1}; {:.2}x)",
            t_aff * 1e9 / m as f64,
            t_jac * 1e9 / m as f64,
            t_jac / t_aff
        );
        results.record("ff 2^16 msm lane batch-affine ns/point", t_aff * 1e9 / m as f64);
        results.record("ff 2^16 msm jacobian ns/point", t_jac * 1e9 / m as f64);

        let n: usize = 1 << 16;
        let mut rng = Rng::new(7);
        let base: Vec<FrBn254> = (0..n).map(|_| FrBn254::random(&mut rng)).collect();
        let plan = ntt::NttPlan::<ifzkp::ff::params::Bn254FrParams, 4>::new(n).unwrap();
        let mut serial = base.clone();
        let sw = Stopwatch::start();
        ntt::ntt_in_place(&mut serial, &plan.omega);
        let t_serial = sw.secs();
        let mut planned = base.clone();
        let sw = Stopwatch::start();
        plan.ntt(&mut planned, 1);
        let t_planned = sw.secs();
        assert_eq!(planned, serial, "lane-fed planned NTT diverged at 2^16");
        println!(
            "ff 2^16 NTT lane planned x1                  {:>10.1} ns/element  (vs reference {:.1}; {:.2}x)",
            t_planned * 1e9 / n as f64,
            t_serial * 1e9 / n as f64,
            t_serial / t_planned
        );
        results.record("ff 2^16 ntt lane planned x1 ns/element", t_planned * 1e9 / n as f64);
        results.record("ff 2^16 ntt serial reference ns/element", t_serial * 1e9 / n as f64);
    }

    // chunk-parallel runtime vs window-parallel at 2^16 (the acceptance
    // point): under GLV the plan has only 11 windows, so window-parallel
    // backends cap at 11 useful threads while the chunked backend keeps
    // scaling with the point partition. Phases (recode/fill/merge/reduce)
    // land in the JSON artifact so the perf trajectory is recorded.
    //
    // Deliberately NOT scaled down by IFZKP_BENCH_QUICK: the CI artifact
    // is produced in quick mode, and the comparison is only meaningful at
    // the 2^16 operating point — two MSMs, bounded at seconds.
    {
        let m_chunk: usize = 1 << 16;
        let w = points::workload::<Bn254G1>(m_chunk, 3);
        let glv_cfg = MsmConfig::new(12, Reduction::Recursive { k2: 6 }).glv();
        let windows = MsmPlan::for_curve::<Bn254G1>(&glv_cfg).windows as usize;
        let host = msm::parallel::default_threads();
        // threads > windows even on small CI runners: take the max
        let threads = host.max(windows + 5);
        let sw = Stopwatch::start();
        let par = msm::parallel::msm(&w.points, &w.scalars, &glv_cfg, threads);
        let t_par = sw.secs();
        println!(
            "BN254 MSM 2^16 glv parallel x{threads} ({windows} windows) {:>10.1} ns/point",
            t_par * 1e9 / m_chunk as f64
        );
        // stable JSON keys (no host-dependent thread count), so the
        // artifact stays diffable run over run; the width is its own entry
        results.record("BN254 MSM 2^16 glv wide threads", threads as f64);
        results.record(
            "BN254 MSM 2^16 glv parallel-wide ns/point",
            t_par * 1e9 / m_chunk as f64,
        );
        let sw = Stopwatch::start();
        let (chk, phases) =
            msm::chunked::msm_with_phases(&w.points, &w.scalars, &glv_cfg, threads);
        let t_chk = sw.secs();
        assert!(chk.eq_point(&par), "chunked != parallel result");
        println!(
            "BN254 MSM 2^16 glv chunked  x{threads} ({windows} windows) {:>10.1} ns/point  ({:.2}x vs window-parallel)",
            t_chk * 1e9 / m_chunk as f64,
            t_par / t_chk
        );
        results.record(
            "BN254 MSM 2^16 glv chunked-wide ns/point",
            t_chk * 1e9 / m_chunk as f64,
        );
        for (phase, secs) in [
            ("recode", phases.recode_s),
            ("fill", phases.fill_s),
            ("merge", phases.merge_s),
            ("reduce", phases.reduce_s),
        ] {
            println!(
                "  chunked phase {phase:<28} {:>10.1} ns/point  ({:.1}% of phases)",
                secs * 1e9 / m_chunk as f64,
                100.0 * secs / phases.total_s().max(1e-12),
            );
            results.record(
                &format!("BN254 MSM 2^16 chunked {phase} ns/point"),
                secs * 1e9 / m_chunk as f64,
            );
        }
    }

    // fixed-base precompute tables at 2^16 GLV (the point-cache PR's
    // acceptance point): the per-window doubling/shift chain moves into a
    // one-time build, the per-call fill reads table slot -> bucket through
    // the batch-affine accumulator (zero doublings in fill AND combine),
    // and the ablation sweeps window width to plot speedup vs table size.
    //
    // Like the chunked section: NOT scaled by IFZKP_BENCH_QUICK — the
    // comparison only means something at 2^16, and it is bounded at
    // seconds. Keys are host-independent and stable.
    {
        let m_tab: usize = 1 << 16;
        let w = points::workload::<Bn254G1>(m_tab, 3);
        let glv_cfg = MsmConfig::new(12, Reduction::Recursive { k2: 6 }).glv();
        let sw = Stopwatch::start();
        let (live, live_cost) = pippenger::msm_with_cost(&w.points, &w.scalars, &glv_cfg);
        let t_live = sw.secs();
        results.record("BN254 MSM 2^16 glv pippenger ns/point", t_live * 1e9 / m_tab as f64);
        let sw = Stopwatch::start();
        let table = msm::PrecompTable::<Bn254G1>::build(&w.points, &glv_cfg);
        let t_build = sw.secs();
        println!(
            "BN254 MSM 2^16 table build (k=12 glv)        {:>10.1} ns/point  ({:.2}s once per SRS, {} MiB)",
            t_build * 1e9 / m_tab as f64,
            t_build,
            table.bytes() >> 20
        );
        results.record("BN254 MSM 2^16 table build ns/point", t_build * 1e9 / m_tab as f64);
        let sw = Stopwatch::start();
        let (fed, cost) = table.msm_with_cost(&w.scalars);
        let t_fed = sw.secs();
        assert!(fed.eq_point(&live), "table-fed != live pippenger");
        // the structural wins, measured: no doublings anywhere in fill or
        // combine, and the fill's point-op count collapses (batched affine
        // lanes run in the field layer; live fills pay a Jacobian mixed
        // add per nonzero digit)
        assert_eq!(cost.fill.double, 0, "table fill issued doublings");
        assert_eq!(cost.combine.double, 0, "table combine issued doublings");
        assert!(
            cost.fill.total() < live_cost.fill_ops,
            "fill-phase point ops did not drop: {} vs {}",
            cost.fill.total(),
            live_cost.fill_ops
        );
        println!(
            "BN254 MSM 2^16 glv table-fed (k=12)          {:>10.1} ns/point  ({:.2}x vs pippenger; fill point-ops {} vs {}, fill+combine doubles 0)",
            t_fed * 1e9 / m_tab as f64,
            t_live / t_fed,
            cost.fill.total(),
            live_cost.fill_ops,
        );
        results.record("BN254 MSM 2^16 glv table-fed ns/point", t_fed * 1e9 / m_tab as f64);

        // ablation: speedup vs table size as the window width sweeps (the
        // `tables --id pointcache` plot, pinned into the JSON artifact)
        for k in [8u32, 10, 12] {
            let cfg = MsmConfig::new(k, Reduction::Recursive { k2: 4 }).glv();
            let sw = Stopwatch::start();
            let base = msm::msm_pippenger(&w.points, &w.scalars, &cfg);
            let t_base = sw.secs();
            let tab = msm::PrecompTable::<Bn254G1>::build(&w.points, &cfg);
            let sw = Stopwatch::start();
            let out = tab.msm(&w.scalars);
            let t_tab = sw.secs();
            assert!(out.eq_point(&base), "k={k} table-fed diverged");
            println!(
                "BN254 MSM 2^16 table k={k:<2} ({} cols, {:>4} MiB) {:>10.1} ns/point  ({:.2}x vs pippenger k={k})",
                tab.windows(),
                tab.bytes() >> 20,
                t_tab * 1e9 / m_tab as f64,
                t_base / t_tab,
            );
            results.record(
                &format!("BN254 MSM 2^16 table k={k} ns/point"),
                t_tab * 1e9 / m_tab as f64,
            );
            results.record(
                &format!("BN254 MSM 2^16 table k={k} pippenger ns/point"),
                t_base * 1e9 / m_tab as f64,
            );
            results.record(&format!("BN254 table k={k} bytes"), tab.bytes() as f64);
        }
    }

    // parallel scaling
    for threads in [1usize, 2, 4] {
        let w = points::workload::<Bn254G1>(msm_m, 3);
        let cfg = MsmConfig::default();
        let sw = Stopwatch::start();
        let out = msm::parallel::msm(&w.points, &w.scalars, &cfg, threads);
        let t = sw.secs();
        std::hint::black_box(out);
        println!(
            "BN254 MSM {msm_label} parallel x{threads}                  {:>12.1} ns/point",
            t * 1e9 / msm_m as f64
        );
        results.record(&format!("BN254 MSM {msm_label} parallel x{threads} ns/point"), t * 1e9 / msm_m as f64);
    }

    // sharded multi-device path: the coordinator's fan-out/merge, in
    // process (1 device = the unsharded baseline)
    let w = points::workload::<Bn254G1>(msm_m, 3);
    let cfg = MsmConfig::default();
    let mut base_s = 0.0f64;
    for devices in [1usize, 2, 4] {
        for policy in [ShardPolicy::ChunkPoints, ShardPolicy::WindowRange] {
            if devices == 1 && policy == ShardPolicy::WindowRange {
                continue; // one device has no window split
            }
            let pool = ShardPool::<Bn254G1>::native(devices, 1).with_policy(policy);
            let sw = Stopwatch::start();
            let out = pool.execute(&w.points, &w.scalars, &cfg).expect("pool msm");
            let t = sw.secs();
            std::hint::black_box(out);
            if devices == 1 {
                base_s = t;
            }
            let tag = format!("sharded x{devices} {policy:?}");
            println!(
                "BN254 MSM {msm_label} {tag:<28} {:>10.1} ns/point  ({:.2}x vs 1 device)",
                t * 1e9 / msm_m as f64,
                base_s / t
            );
            results.record(&format!("BN254 MSM {msm_label} {tag} ns/point"), t * 1e9 / msm_m as f64);
        }
    }

    // NTT (small n continuity entry: the serial reference, historic key)
    let mut rng = Rng::new(4);
    let ntt_n: usize = if quick { 1 << 10 } else { 1 << 14 };
    let dom = ntt::domain::Domain::<ifzkp::ff::params::Bn254FrParams, 4>::new(ntt_n).unwrap();
    let mut v: Vec<FrBn254> = (0..ntt_n).map(|_| FrBn254::random(&mut rng)).collect();
    let sw = Stopwatch::start();
    let reps = 10;
    for _ in 0..reps {
        ntt::ntt_in_place(&mut v, &dom.omega);
    }
    let t = sw.secs() / reps as f64;
    println!(
        "NTT {} (BN254 Fr)                          {:>12.1} ns/element  ({:.1}ms per transform)",
        if quick { "2^10" } else { "2^14" },
        t * 1e9 / ntt_n as f64,
        t * 1e3
    );
    results.record("NTT ns/element", t * 1e9 / ntt_n as f64);

    // NTT runtime section: serial reference vs the cached-plan executors
    // at 2^16 (the acceptance operating point), plus the prover-shaped
    // transform set through one cached plan. Like the chunked-MSM 2^16
    // section, deliberately NOT scaled by IFZKP_BENCH_QUICK — the
    // comparison only means something at this size, and it is bounded at
    // seconds. JSON keys stay host-independent; the thread width is its
    // own entry.
    {
        use ifzkp::ntt::{parallel as nttpar, NttPlan};
        let n: usize = 1 << 16;
        let mut rng = Rng::new(5);
        let base: Vec<FrBn254> = (0..n).map(|_| FrBn254::random(&mut rng)).collect();

        let sw = Stopwatch::start();
        let plan = NttPlan::<ifzkp::ff::params::Bn254FrParams, 4>::new(n).unwrap();
        let t_build = sw.secs();
        println!(
            "NTT 2^16 plan build (twiddles+ladders)       {:>10.1} ns/element  ({:.2}ms once per size)",
            t_build * 1e9 / n as f64,
            t_build * 1e3
        );
        results.record("NTT 2^16 plan build ns/element", t_build * 1e9 / n as f64);

        let mut serial = base.clone();
        let sw = Stopwatch::start();
        ntt::ntt_in_place(&mut serial, &plan.omega);
        let t_serial = sw.secs();
        println!("NTT 2^16 serial reference                    {:>10.1} ns/element", t_serial * 1e9 / n as f64);
        results.record("NTT 2^16 serial ns/element", t_serial * 1e9 / n as f64);

        let mut planned = base.clone();
        let sw = Stopwatch::start();
        plan.ntt(&mut planned, 1);
        let t_planned = sw.secs();
        assert_eq!(planned, serial, "planned x1 != serial reference");
        println!(
            "NTT 2^16 planned x1 (cached twiddles)        {:>10.1} ns/element  ({:.2}x vs reference)",
            t_planned * 1e9 / n as f64,
            t_serial / t_planned
        );
        results.record("NTT 2^16 planned x1 ns/element", t_planned * 1e9 / n as f64);

        // threads > 4 even on small CI runners: the acceptance point is
        // "parallel beats serial at >= 4 threads"
        let threads = msm::parallel::default_threads().max(4);
        results.record("NTT 2^16 wide threads", threads as f64);

        let mut par = base.clone();
        let sw = Stopwatch::start();
        plan.ntt(&mut par, threads); // auto: four-step at 2^16
        let t_par = sw.secs();
        assert_eq!(par, serial, "parallel != serial reference");
        println!(
            "NTT 2^16 parallel x{threads} (four-step)           {:>10.1} ns/element  ({:.2}x vs serial)",
            t_par * 1e9 / n as f64,
            t_serial / t_par
        );
        results.record("NTT 2^16 parallel-wide ns/element", t_par * 1e9 / n as f64);

        let mut stg = base.clone();
        let sw = Stopwatch::start();
        nttpar::ntt_stage_parallel(&plan, &mut stg, threads);
        let t_stg = sw.secs();
        assert_eq!(stg, serial, "stage-parallel != serial reference");
        println!(
            "NTT 2^16 stage-parallel x{threads}                 {:>10.1} ns/element  ({:.2}x vs serial)",
            t_stg * 1e9 / n as f64,
            t_serial / t_stg
        );
        results.record("NTT 2^16 stage-parallel-wide ns/element", t_stg * 1e9 / n as f64);

        // prover-shaped sequence: the QAP reduction's seven transforms
        // (3 iNTT, 3 coset NTT, 1 coset iNTT) through the one cached
        // plan — the per-phase split lands in the JSON artifact
        let mut a = base.clone();
        let mut b = base.clone();
        let mut c = base.clone();
        let sw = Stopwatch::start();
        plan.intt(&mut a, threads);
        plan.intt(&mut b, threads);
        plan.intt(&mut c, threads);
        let t_intt = sw.secs();
        let sw = Stopwatch::start();
        plan.coset_ntt(&mut a, threads);
        plan.coset_ntt(&mut b, threads);
        plan.coset_ntt(&mut c, threads);
        let t_coset = sw.secs();
        let sw = Stopwatch::start();
        plan.coset_intt(&mut a, threads);
        let t_icoset = sw.secs();
        // the phase entries are guarded too: intt → coset_ntt →
        // coset_intt is net one inverse transform of the base vector
        let mut check = base.clone();
        plan.intt(&mut check, 1);
        assert_eq!(a, check, "prover-phase chain diverged");
        for (phase, secs, count) in [
            ("intt", t_intt, 3usize),
            ("coset-ntt", t_coset, 3),
            ("coset-intt", t_icoset, 1),
        ] {
            println!(
                "  NTT 2^16 prover phase {phase:<20} {:>10.1} ns/element  ({count} transforms)",
                secs * 1e9 / (count * n) as f64
            );
            results.record(
                &format!("NTT 2^16 prover {phase} ns/element"),
                secs * 1e9 / (count * n) as f64,
            );
        }
    }

    // engine (if artifacts present): batched UDA throughput
    let dir = ifzkp::runtime::artifact::default_dir();
    if dir.join("manifest.json").exists() && std::env::var("IFZKP_BENCH_ENGINE").is_ok() {
        println!("\n== PJRT UDA engine ==");
        let ctx = ifzkp::runtime::PjrtContext::cpu().unwrap();
        let manifest = ifzkp::runtime::ArtifactManifest::load(&dir).unwrap();
        let sw = Stopwatch::start();
        let engine = ifzkp::runtime::UdaEngine::<Bn254G1>::load(&ctx, &manifest).unwrap();
        println!("artifact compile: {:.1}s", sw.secs());
        let b = engine.batch();
        let pts = points::generate_points_walk::<Bn254G1>(2 * b, 5);
        let pairs: Vec<(Jacobian<Bn254G1>, Jacobian<Bn254G1>)> =
            (0..b).map(|i| (pts[i].to_jacobian(), pts[i + b].to_jacobian())).collect();
        let _ = engine.uda_batch(&pairs).unwrap(); // warm
        let sw = Stopwatch::start();
        let reps = 20;
        for _ in 0..reps {
            let _ = engine.uda_batch(&pairs).unwrap();
        }
        let t = sw.secs() / reps as f64;
        println!(
            "engine UDA batch={b}: {:.2} ms/batch = {:.1} us/point-op",
            t * 1e3,
            t * 1e6 / b as f64
        );
    } else {
        println!("\n(engine bench skipped: set IFZKP_BENCH_ENGINE=1 with artifacts built)");
    }

    results.emit_json();
}
