//! Bench: regenerate Figure 8 (FPGA vs GPU normalized throughput and
//! per-watt, BLS12-381).

fn main() {
    println!("{}", ifzkp::report::figures::fig8_fpga_vs_gpu());
}
