//! Bench: regenerate Tables IV, V and VII (resource model vs the paper's
//! synthesis results).

fn main() {
    println!("{}", ifzkp::report::tables::table4_5());
    println!("{}", ifzkp::report::tables::table7());
}
