//! Memory bench: peak resident bytes and wall time, resident vs streaming
//! prover (the `BENCH_memory.json` artifact — schema in the repo-root
//! `BENCHMARKS.md`).
//!
//! For each circuit size the resident prover runs once (its Θ(m) working
//! set is the accounted point+scalar bytes of the full SRS), then the
//! streaming prover runs at several budgets that are small fractions of
//! that working set. Every streamed proof is asserted bit-identical to the
//! resident one before its row is recorded, so the artifact only ever
//! plots correct runs.
//!
//! CI knobs (same as `hotpath`):
//! * `IFZKP_BENCH_QUICK=1` — small-n smoke (seconds, not minutes);
//! * `IFZKP_BENCH_JSON=path` — write the rows as JSON.

use ifzkp::ec::{Bn254G1, Bn254G2, CurveParams};
use ifzkp::ff::params::Bn254FrParams;
use ifzkp::snark::setup::CrsBn254;
use ifzkp::snark::{circuits, prove_streaming, Prover, ProverConfig, StreamingSrs};
use ifzkp::util::json::Json;
use ifzkp::util::mem::{MemoryBudget, SCALAR_BYTES};
use ifzkp::util::{human_count, human_secs, Stopwatch};

/// One artifact row.
struct Row {
    name: String,
    constraints: usize,
    mode: &'static str,
    budget_bytes: u64,
    peak_bytes: u64,
    fixed_bytes: u64,
    wall_s: f64,
}

fn emit_json(rows: &[Row]) {
    let Ok(path) = std::env::var("IFZKP_BENCH_JSON") else {
        return;
    };
    let mut arr = Vec::with_capacity(rows.len());
    for r in rows {
        let mut j = Json::obj();
        j.set("name", r.name.as_str())
            .set("constraints", r.constraints)
            .set("mode", r.mode)
            .set("budget_bytes", r.budget_bytes)
            .set("peak_bytes", r.peak_bytes)
            .set("fixed_bytes", r.fixed_bytes)
            .set("wall_s", r.wall_s);
        arr.push(j);
    }
    let mut root = Json::obj();
    root.set("bench", "memory").set("results", Json::Arr(arr));
    match std::fs::write(&path, format!("{root}\n")) {
        Ok(()) => println!("\nwrote bench JSON: {path}"),
        Err(e) => eprintln!("\nfailed to write bench JSON {path}: {e}"),
    }
}

/// Accounted Θ(m) working set of the resident prover: the five SRS point
/// queries plus the scalar vectors the MSMs consume.
fn resident_working_set(nv: usize, domain_n: usize) -> u64 {
    let h_len = domain_n.saturating_sub(1) as u64;
    let nv = nv as u64;
    let points = 3 * nv * Bn254G1::AFFINE_BYTES       // a, b1, l
        + h_len * Bn254G1::AFFINE_BYTES               // h
        + nv * Bn254G2::AFFINE_BYTES; // b2
    let scalars = (nv + h_len) * SCALAR_BYTES;
    points + scalars
}

fn main() {
    let quick = std::env::var("IFZKP_BENCH_QUICK").is_ok();
    let sizes: &[usize] = if quick { &[1 << 12, 1 << 14] } else { &[1 << 14, 1 << 16, 1 << 20] };
    // budgets as fractions of the resident working set — the plot's x-axis
    let divisors: &[u64] = if quick { &[4, 16] } else { &[4, 16, 64] };
    let seed = 20240710u64;
    let mut rows = Vec::new();
    let mode = if quick { " (quick)" } else { "" };
    println!("== memory bench: resident vs streaming prover{mode} ==");
    for &n in sizes {
        let tag = format!("2^{}", n.trailing_zeros());
        let cs = circuits::mul_chain::<Bn254FrParams, 4>(n, seed);
        let domain_n = cs.num_constraints().max(2).next_power_of_two();
        let nv = cs.num_variables();
        let ws = resident_working_set(nv, domain_n);

        let crs = CrsBn254::synthesize(nv, domain_n, seed);
        let prover = Prover::<_, _, Bn254FrParams>::new(crs);
        let sw = Stopwatch::start();
        let (want, _) = prover.prove(&cs);
        let t_resident = sw.secs();
        println!(
            "prove {tag} resident                 {:>10}  working set {:>12} B",
            human_secs(t_resident),
            ws
        );
        rows.push(Row {
            name: format!("prove {tag} resident"),
            constraints: n,
            mode: "resident",
            budget_bytes: 0,
            peak_bytes: ws,
            fixed_bytes: 0,
            wall_s: t_resident,
        });
        // the resident SRS is no longer needed; the streaming runs below
        // source their chunks from the generator walk
        drop(prover);

        let srs = StreamingSrs::<Bn254G1, Bn254G2>::generated(nv, domain_n, seed);
        let floor = 2 * (Bn254G2::AFFINE_BYTES + SCALAR_BYTES);
        for &div in divisors {
            let budget = MemoryBudget::bytes((ws / div).max(floor));
            let (got, report) = prove_streaming(&cs, &srs, budget, &ProverConfig::default())
                .expect("streaming prove");
            assert!(
                got.a.eq_point(&want.a) && got.b.eq_point(&want.b) && got.c.eq_point(&want.c),
                "streamed proof at budget ws/{div} diverged from resident ({tag})"
            );
            println!(
                "prove {tag} streaming ws/{div:<4}        {:>10}  chunk peak {:>12} B of {} B  (chunks {} G1 / {} G2, fixed {} B)",
                human_secs(report.total_s),
                report.peak_chunk_bytes,
                report.budget_bytes,
                human_count(report.chunk_points_g1 as u64),
                human_count(report.chunk_points_g2 as u64),
                report.fixed_bytes
            );
            assert!(
                report.peak_chunk_bytes <= report.budget_bytes,
                "accounted peak {} exceeded budget {} ({tag} ws/{div})",
                report.peak_chunk_bytes,
                report.budget_bytes
            );
            rows.push(Row {
                name: format!("prove {tag} streaming ws/{div}"),
                constraints: n,
                mode: "streaming",
                budget_bytes: report.budget_bytes,
                peak_bytes: report.peak_chunk_bytes,
                fixed_bytes: report.fixed_bytes,
                wall_s: report.total_s,
            });
        }
    }
    emit_json(&rows);
}
