//! Bench: regenerate Table I (prover profiling split).
//!
//! Runs the instrumented Groth16-shaped prover on both curve families and
//! prints the measured MSM-G1 / MSM-G2 / NTT / other percentages next to
//! the paper's row. Size via IFZKP_BENCH_CONSTRAINTS (default 2^13).

fn main() {
    let n: usize = std::env::var("IFZKP_BENCH_CONSTRAINTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1 << 13);
    println!("{}", ifzkp::report::tables::table1(n, 20240710));
    println!("note: paper profiled libsnark at production sizes (up to 2^27);");
    println!("the split converges toward the paper's as n grows (G2 share rises).");
}
