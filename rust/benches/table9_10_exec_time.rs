//! Bench: regenerate Tables IX and X (execution-time comparison CPU / GPU /
//! FPGA for BLS12-381, and the 64M summary).
//!
//! The CPU column is both modeled (libsnark-calibrated) and measured (this
//! crate's parallel MSM, for sizes up to IFZKP_BENCH_CPU_MEASURE, default
//! 2^17 — keeps `cargo bench` fast; raise it for a fuller sweep).

fn main() {
    let cap: usize = std::env::var("IFZKP_BENCH_CPU_MEASURE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1 << 17);
    println!("{}", ifzkp::report::tables::table9(cap));
    println!("{}", ifzkp::report::tables::table10());
}
