//! Bench: regenerate Table VIII (standby/active power model vs paper).

fn main() {
    println!("{}", ifzkp::report::tables::table8());
}
