//! Bench: regenerate Tables II + III (measured modmul counts: naive
//! double-and-add vs the bucket method at the hardware window k=12),
//! plus the IS-RBAM ablation table.

fn main() {
    let m: usize = std::env::var("IFZKP_BENCH_MSM_SIZE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1024);
    println!("{}", ifzkp::report::tables::table2_3(m, 20240710));
    println!("{}", ifzkp::report::tables::ablation_reduction());
}
