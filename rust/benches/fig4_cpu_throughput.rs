//! Bench: regenerate Figure 4 (CPU MSM throughput vs size, M-MSM-PPS).
//!
//! Prints the libsnark-calibrated model series plus locally measured rows
//! for sizes this host can execute quickly.

use ifzkp::baseline::cpu;
use ifzkp::ec::{Bls12381G1, Bn254G1};

fn main() {
    println!("{}", ifzkp::report::figures::fig4_cpu_throughput());

    println!("# measured on this host (serial Pippenger)");
    println!("msm_size,bn128_mpps_measured,bls12_381_mpps_measured");
    for m in [1usize << 10, 1 << 12, 1 << 14, 1 << 16] {
        let bn = cpu::measure_serial::<Bn254G1>(m, 0xF164 + m as u64);
        let bls = cpu::measure_serial::<Bls12381G1>(m, 0xF164 + m as u64);
        println!("{m},{:.4},{:.4}", bn.mpps, bls.mpps);
    }
}
