//! Bench: regenerate Figure 6 (FPGA throughput across curve and scaling).

fn main() {
    println!("{}", ifzkp::report::figures::fig6_fpga_throughput());
}
