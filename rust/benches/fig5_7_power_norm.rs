//! Bench: regenerate Figures 5 and 7 (power-normalized FPGA throughput,
//! S=1 vs S=2, per curve).

use ifzkp::fpga::CurveId;

fn main() {
    println!("{}", ifzkp::report::figures::fig5_7_power_normalized(CurveId::Bn254));
    println!("{}", ifzkp::report::figures::fig5_7_power_normalized(CurveId::Bls12381));
}
