"""L2: the jit-able compute graphs that get AOT-compiled for the rust host.

Two graph families per curve:

* ``uda_batch`` — one batched UDA step (the paper's point processor): six
  (B, nl) u32 coordinate arrays in, three out. The rust BAM drives bucket
  accumulation by repeatedly invoking this executable on conflict-free
  batches — exactly how the hardware BAM feeds its pipelined UDA.
* ``uda_chain`` — ``steps`` dependent UDA applications folded inside one
  executable (lax-unrolled): amortizes host↔engine transfer for the serial
  reduction phases; used by the perf pass to pick the sweet spot.

Python is build-time only; the rust runtime loads the lowered HLO text.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from . import params
from .kernels import point_ops


def uda_batch_fn(curve: params.Curve, block: int = 64):
    """Returns f(x1,y1,z1,x2,y2,z2) -> (x3,y3,z3), all (B, nl) u32."""
    kernel = point_ops.uda_pallas(curve, block=block)

    def f(x1, y1, z1, x2, y2, z2):
        return kernel(x1, y1, z1, x2, y2, z2)

    return f

def uda_chain_fn(curve: params.Curve, steps: int, block: int = 64):
    """Returns f(x1..z2) that applies UDA `steps` times, folding the result
    into the accumulator side each step: acc <- UDA(acc, operand). The
    operand arrays are reused every step (useful shape for doubling chains:
    pass the same point and it doubles `steps` times)."""
    kernel = point_ops.uda_pallas(curve, block=block)

    def f(x1, y1, z1, x2, y2, z2):
        ax, ay, az = x1, y1, z1
        for _ in range(steps):
            ax, ay, az = kernel(ax, ay, az, x2, y2, z2)
        return ax, ay, az

    return f


def example_args(curve: params.Curve, batch: int):
    """ShapeDtypeStructs for lowering."""
    nl = curve.nlimb16
    spec = jax.ShapeDtypeStruct((batch, nl), jnp.uint32)
    return tuple([spec] * 6)
