"""L1 Pallas kernel: batched Montgomery modular multiplication, 16-bit limbs.

The paper's entire point processor reduces to a handful of modular
operations (§IV-B1); the multiplier is the resource/latency driver. The
hardware insight — replace the 3-integer-multiplier Montgomery pipeline
with a single multiplier plus table-based reduction in carry-save form
(§IV-B4) — maps to vectors as follows:

* 16-bit limbs (`NLIMB16` per element) so every partial product and every
  delayed-carry column sum fits a u64 lane with headroom (the carry-save
  analogue: no carry chains inside the accumulation loop);
* one fused product/column pass, then an interleaved Montgomery reduction
  whose per-limb quotient digit `m = (t·(−p⁻¹)) mod 2¹⁶` is a pure lane-
  local multiply — the software stand-in for the paper's M20K lookup;
* a single carry-propagation + conditional-subtract epilogue.

REPRESENTATION (perf-critical, see EXPERIMENTS.md §Perf/L1): limbs are
carried through the computation as a **python list of (B,) u64 vectors**
("lanes"), not as one (B, nl) tensor. Limb indexing then happens at trace
time, so the lowered HLO is pure element-wise arithmetic — zero
dynamic-update-slice ops. The first formulation used `.at[:, i].add(...)`
scatters; XLA took ~280 s to compile the resulting UDA graph vs ~3 s for
the lane form, and the artifact is ~5× smaller. The prime's limbs enter as
python-int literals (folded into the graph), so kernels take no parameter
input.

Everything is batched over a leading dimension; the Pallas grid tiles that
dimension in VMEM-sized blocks (`BLOCK`). `interpret=True` everywhere: the
CPU PJRT client cannot execute Mosaic custom-calls (see DESIGN.md
§Hardware-Adaptation for the real-TPU notes).
"""

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..params import Curve

MASK16 = 0xFFFF  # python int: folds as a literal


def lanes(x, nl):
    """(B, nl) array -> list of nl (B,) u64 lanes."""
    x = x.astype(jnp.uint64)
    return [x[:, i] for i in range(nl)]


def unlanes(ls):
    """list of (B,) lanes -> (B, nl) array."""
    return jnp.stack(ls, axis=1)


def _column_products(a, b, nl):
    """Delayed-carry column sums of the schoolbook product.

    a, b: lane lists of 16-bit values. Returns 2*nl lanes with column
    k = sum_{i+j=k} a_i * b_j (each < nl * 2^32 — no overflow in u64).
    """
    cols = [None] * (2 * nl)
    for i in range(nl):
        for j in range(nl):
            prod = a[i] * b[j]
            k = i + j
            cols[k] = prod if cols[k] is None else cols[k] + prod
    zero = jnp.zeros_like(a[0])
    return [c if c is not None else zero for c in cols]


def _mont_reduce(t, p_limbs, inv16, nl):
    """Interleaved Montgomery reduction of delayed-carry columns.

    t: 2*nl lanes of column sums; p_limbs: python ints. Returns nl clean
    16-bit lanes of a*b*R^-1 mod p, canonical (< p).
    """
    t = list(t)
    for i in range(nl):
        m = ((t[i] & MASK16) * inv16) & MASK16  # quotient digit
        for j in range(nl):
            if p_limbs[j]:
                t[i + j] = t[i + j] + m * p_limbs[j]
        # t[i] ≡ 0 mod 2^16 now; push its upper bits into column i+1.
        t[i + 1] = t[i + 1] + (t[i] >> 16)
    res = t[nl:]
    out = []
    carry = None
    for i in range(nl):
        v = res[i] if carry is None else res[i] + carry
        out.append(v & MASK16)
        carry = v >> 16
    # Montgomery bound: result < 2p < 2^(16·nl) ⇒ final carry == 0.
    return _cond_sub_p(out, p_limbs, nl)


def _cond_sub_p(x, p_limbs, nl):
    """If x >= p subtract p (branchless borrow chain over lanes)."""
    diff = []
    borrow = None
    for i in range(nl):
        d = x[i] - p_limbs[i] if borrow is None else x[i] - p_limbs[i] - borrow
        borrow = (d >> 63) & 1  # wraparound ⇒ borrowed
        diff.append(d & MASK16)
    ge = borrow == 0  # no final borrow -> x >= p
    return [jnp.where(ge, d, xi) for d, xi in zip(diff, x)]


def mod_add(a, b, p_limbs, nl):
    """(a + b) mod p over lanes."""
    s = []
    carry = None
    for i in range(nl):
        v = a[i] + b[i] if carry is None else a[i] + b[i] + carry
        s.append(v & MASK16)
        carry = v >> 16
    # a, b < p ⇒ sum < 2p < 2^(16·nl): carry == 0.
    return _cond_sub_p(s, p_limbs, nl)


def mod_sub(a, b, p_limbs, nl):
    """(a - b) mod p over lanes."""
    d = []
    borrow = None
    for i in range(nl):
        v = a[i] - b[i] if borrow is None else a[i] - b[i] - borrow
        borrow = (v >> 63) & 1
        d.append(v & MASK16)
    underflow = borrow == 1
    withp = []
    carry = None
    for i in range(nl):
        v = d[i] + p_limbs[i] if carry is None else d[i] + p_limbs[i] + carry
        withp.append(v & MASK16)
        carry = v >> 16
    return [jnp.where(underflow, w, di) for w, di in zip(withp, d)]


def mont_mul_lanes(a, b, curve: Curve):
    """Montgomery product over lanes."""
    nl = curve.nlimb16
    t = _column_products(a, b, nl)
    return _mont_reduce(t, curve.limbs16(curve.p), curve.inv16, nl)


def mont_mul(a, b, curve: Curve):
    """Montgomery product over (B, nl) arrays (test/reference entry)."""
    nl = curve.nlimb16
    return unlanes(mont_mul_lanes(lanes(a, nl), lanes(b, nl), curve))


def _modmul_kernel_body(curve: Curve):
    nl = curve.nlimb16
    p_limbs = curve.limbs16(curve.p)
    inv16 = curve.inv16

    def kernel(a_ref, b_ref, o_ref):
        a = lanes(a_ref[...], nl)
        b = lanes(b_ref[...], nl)
        t = _column_products(a, b, nl)
        out = _mont_reduce(t, p_limbs, inv16, nl)
        o_ref[...] = unlanes(out).astype(jnp.uint32)

    return kernel


@functools.lru_cache(maxsize=None)
def modmul_pallas(curve: Curve, block: int = 64):
    """Build the batched Pallas modmul: (B, nl) u32 × (B, nl) u32 → same.

    The grid walks the batch dimension in `block`-row tiles; each tile's
    operands live in VMEM for the whole fused multiply-reduce (the BlockSpec
    is the software form of the paper's "feed the pipelined multiplier a new
    operand every cycle"). Cached per (curve, block) so the jit trace is
    paid once per process.
    """
    nl = curve.nlimb16

    @jax.jit
    def call(a, b):
        batch = a.shape[0]
        assert batch % block == 0, f"batch {batch} % block {block} != 0"
        grid = (batch // block,)
        spec = pl.BlockSpec((block, nl), lambda i: (i, 0))
        return pl.pallas_call(
            _modmul_kernel_body(curve),
            out_shape=jax.ShapeDtypeStruct((batch, nl), jnp.uint32),
            grid=grid,
            in_specs=[spec, spec],
            out_specs=spec,
            interpret=True,
        )(a, b)

    return call
