"""Pure python-int oracles for the L1/L2 kernels.

Everything here is computed with arbitrary-precision integers and the
textbook formulas — no JAX, no limbs. The pytest suites check the Pallas
kernel and the AOT'd HLO against these, and the rust side is checked against
the same math through its own tests, closing the cross-language loop.
"""

from ..params import Curve

# EFD add-2007-bl / dbl-2009-l over Jacobian (X, Y, Z), a = 0.
# Points are triples of canonical ints; infinity is Z == 0.
INF = (0, 1, 0)


def jac_is_inf(p):
    return p[2] == 0


def jac_double(p, curve: Curve):
    """dbl-2009-l (a=0)."""
    P = curve.p
    x1, y1, z1 = p
    if z1 == 0:
        return INF
    a = x1 * x1 % P
    b = y1 * y1 % P
    c = b * b % P
    d = 2 * ((x1 + b) * (x1 + b) % P - a - c) % P
    e = 3 * a % P
    f = e * e % P
    x3 = (f - 2 * d) % P
    y3 = (e * (d - x3) - 8 * c) % P
    z3 = 2 * y1 * z1 % P
    return (x3, y3, z3)


def jac_add(p1, p2, curve: Curve):
    """add-2007-bl with unified double/infinity handling (UDA semantics)."""
    P = curve.p
    if jac_is_inf(p1):
        return p2
    if jac_is_inf(p2):
        return p1
    x1, y1, z1 = p1
    x2, y2, z2 = p2
    z1z1 = z1 * z1 % P
    z2z2 = z2 * z2 % P
    u1 = x1 * z2z2 % P
    u2 = x2 * z1z1 % P
    s1 = y1 * z2 * z2z2 % P
    s2 = y2 * z1 * z1z1 % P
    if u1 == u2:
        if s1 == s2:
            return jac_double(p1, curve)
        return INF
    h = (u2 - u1) % P
    i = 4 * h * h % P
    j = h * i % P
    r = 2 * (s2 - s1) % P
    v = u1 * i % P
    x3 = (r * r - j - 2 * v) % P
    y3 = (r * (v - x3) - 2 * s1 * j) % P
    z3 = ((z1 + z2) * (z1 + z2) % P - z1z1 - z2z2) * h % P
    return (x3, y3, z3)


def jac_to_affine(p, curve: Curve):
    if jac_is_inf(p):
        return None
    P = curve.p
    zinv = pow(p[2], -1, P)
    zi2 = zinv * zinv % P
    return (p[0] * zi2 % P, p[1] * zi2 * zinv % P)


def jac_scalar_mul(p, k, curve: Curve):
    """Double-and-add (Algorithm 1 of the paper)."""
    q = INF
    for bit in bin(k)[2:] if k else "":
        q = jac_double(q, curve)
        if bit == "1":
            q = jac_add(q, p, curve)
    return q


def generator_jac(curve: Curve):
    x, y = curve.g1
    return (x, y, 1)


def is_on_curve_jac(p, curve: Curve):
    if jac_is_inf(p):
        return True
    P = curve.p
    x, y, z = p
    z2 = z * z % P
    z6 = z2 * z2 * z2 % P
    return (y * y - x * x * x - curve.b * z6) % P == 0


# --- Montgomery-domain helpers (the engine's number format) ---------------


def mont_mul_int(a_mont, b_mont, curve: Curve):
    """Montgomery product in the R = 2^(16·nlimb) domain."""
    rinv = pow(curve.r16, -1, curve.p)
    return a_mont * b_mont * rinv % curve.p


def point_to_mont_limbs(p, curve: Curve):
    """Jacobian int point -> 3 lists of 16-bit limbs in Montgomery form."""
    return tuple(curve.limbs16(curve.to_mont(c)) for c in p)


def point_from_mont_limbs(limbs3, curve: Curve):
    """Inverse of point_to_mont_limbs."""
    return tuple(curve.from_mont(curve.from_limbs16(c)) for c in limbs3)
