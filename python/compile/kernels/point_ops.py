"""L1 Pallas kernel: the batched Unified Double-Add (UDA) point processor.

§IV-B3 of the paper fuses point-add and point-double into one pipeline: both
datapaths start, a join-mux keyed on a "PD check" (same-point detection)
selects the surviving intermediates, and a shared tail finishes the result.
One operation enters per cycle regardless of whether it is a PA or a PD.

This kernel is that unit re-thought for a batched vector engine (the
DESIGN.md §Hardware-Adaptation mapping): a block of B independent
(accumulator, operand) Jacobian pairs streams in; both the `add-2007-bl`
and `dbl-2009-l` dataflows are evaluated on the whole block; lane-wise
`where` selects play the role of the join-mux. Infinity (Z = 0) and the
P + (−P) → infinity corner follow the same select tree, so the kernel is
total: any pair of curve points in, correct curve point out.

All coordinates are (B, NLIMB16) u32 arrays of 16-bit Montgomery limbs at
the boundary; internally everything is lane lists (see modmul.py's
representation note — this keeps the lowered HLO scatter-free).
"""

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..params import Curve
from .modmul import _column_products, _mont_reduce, lanes, mod_add, mod_sub, unlanes


def _uda_lanes(curve: Curve):
    """Build the lane-level UDA computation."""
    nl = curve.nlimb16
    p_limbs = curve.limbs16(curve.p)
    inv16 = curve.inv16

    def mul(a, b):
        return _mont_reduce(_column_products(a, b, nl), p_limbs, inv16, nl)

    def add(a, b):
        return mod_add(a, b, p_limbs, nl)

    def sub(a, b):
        return mod_sub(a, b, p_limbs, nl)

    def dbl(x):
        return add(x, x)

    def is_zero(x):
        z = x[0] == 0
        for xi in x[1:]:
            z = z & (xi == 0)
        return z

    def eq(a, b):
        e = a[0] == b[0]
        for ai, bi in zip(a[1:], b[1:]):
            e = e & (ai == bi)
        return e

    def select(cond, a, b):
        return [jnp.where(cond, ai, bi) for ai, bi in zip(a, b)]

    def uda(x1, y1, z1, x2, y2, z2):
        inf1 = is_zero(z1)
        inf2 = is_zero(z2)

        # ---- PA branch prefix (add-2007-bl) -----------------------------
        z1z1 = mul(z1, z1)
        z2z2 = mul(z2, z2)
        u1 = mul(x1, z2z2)
        u2 = mul(x2, z1z1)
        s1 = mul(mul(y1, z2), z2z2)
        s2 = mul(mul(y2, z1), z1z1)
        h = sub(u2, u1)
        r = dbl(sub(s2, s1))

        # PD check — the join-mux condition (same x- and y-class).
        pd = eq(u1, u2) & eq(s1, s2) & ~inf1 & ~inf2
        # P + (−P): same x-class, different y ⇒ infinity.
        cancel = eq(u1, u2) & ~eq(s1, s2) & ~inf1 & ~inf2

        # ---- PA tail ----------------------------------------------------
        h2 = dbl(h)
        i = mul(h2, h2)
        j = mul(h, i)
        v = mul(u1, i)
        r2 = mul(r, r)
        x3a = sub(sub(r2, j), dbl(v))
        y3a = sub(mul(r, sub(v, x3a)), dbl(mul(s1, j)))
        zsum = add(z1, z2)
        z3a = mul(sub(sub(mul(zsum, zsum), z1z1), z2z2), h)

        # ---- PD branch (dbl-2009-l on P1, a = 0) ------------------------
        a_ = mul(x1, x1)
        b_ = mul(y1, y1)
        c_ = mul(b_, b_)
        t = add(x1, b_)
        d_ = dbl(sub(sub(mul(t, t), a_), c_))
        e_ = add(dbl(a_), a_)
        f_ = mul(e_, e_)
        x3d = sub(f_, dbl(d_))
        y3d = sub(mul(e_, sub(d_, x3d)), dbl(dbl(dbl(c_))))
        z3d = dbl(mul(y1, z1))

        # ---- join-mux ---------------------------------------------------
        x3 = select(pd, x3d, x3a)
        y3 = select(pd, y3d, y3a)
        z3 = select(pd, z3d, z3a)
        # cancellation → infinity
        zero = [jnp.zeros_like(l) for l in z3]
        z3 = select(cancel, zero, z3)
        # identity cases
        x3 = select(inf1, x2, select(inf2, x1, x3))
        y3 = select(inf1, y2, select(inf2, y1, y3))
        z3 = select(inf1, z2, select(inf2, z1, z3))
        return x3, y3, z3

    return uda


def _uda_kernel_body(curve: Curve):
    nl = curve.nlimb16
    uda = _uda_lanes(curve)

    def kernel(x1, y1, z1, x2, y2, z2, ox, oy, oz):
        args = [lanes(ref[...], nl) for ref in (x1, y1, z1, x2, y2, z2)]
        rx, ry, rz = uda(*args)
        ox[...] = unlanes(rx).astype(jnp.uint32)
        oy[...] = unlanes(ry).astype(jnp.uint32)
        oz[...] = unlanes(rz).astype(jnp.uint32)

    return kernel


@functools.lru_cache(maxsize=None)
def uda_pallas(curve: Curve, block: int = 64):
    """Batched UDA: six (B, nl) u32 inputs → three (B, nl) u32 outputs.

    The Pallas grid tiles the batch in `block` rows; per tile the full UDA
    dataflow (both branches + join-mux) runs out of VMEM. On a real TPU the
    natural tiling is (8·k, 128) lanes with the limb dimension padded onto
    the 128-lane axis; see DESIGN.md §Hardware-Adaptation.
    Cached per (curve, block) so jit tracing amortizes across calls.
    """
    nl = curve.nlimb16

    @jax.jit
    def call(x1, y1, z1, x2, y2, z2):
        batch = x1.shape[0]
        assert batch % block == 0, f"batch {batch} % block {block} != 0"
        grid = (batch // block,)
        spec = pl.BlockSpec((block, nl), lambda i: (i, 0))
        shape = jax.ShapeDtypeStruct((batch, nl), jnp.uint32)
        return pl.pallas_call(
            _uda_kernel_body(curve),
            out_shape=(shape, shape, shape),
            grid=grid,
            in_specs=[spec] * 6,
            out_specs=(spec, spec, spec),
            interpret=True,
        )(x1, y1, z1, x2, y2, z2)

    return call
