"""Canonical curve/field constants for BN254 ("BN128") and BLS12-381.

Single source of truth shared by the L1/L2 kernels, the AOT pipeline and —
via `gen_rust_params.py` — the rust substrate. Every constant is
self-checked on import (Fermat primality witnesses, curve membership,
subgroup order, NTT root existence), so a typo here fails loudly rather
than corrupting test vectors.
"""

# --- BN254 (a.k.a. BN128 / alt_bn128): y^2 = x^3 + 3 over F_p -------------
BN254_P = 21888242871839275222246405745257275088696311157297823662689037894645226208583
BN254_R = 21888242871839275222246405745257275088548364400416034343698204186575808495617
BN254_B = 3
BN254_G1 = (1, 2)
BN254_FR_GEN = 5          # multiplicative generator of F_r
BN254_FR_TWO_ADICITY = 28
BN254_FP_GEN = 3

# BN254 G2 over F_p2 (u^2 = -1), curve y^2 = x^3 + 3/(9+u); EIP-197 generator.
BN254_G2_X = (
    10857046999023057135944570762232829481370756359578518086990519993285655852781,
    11559732032986387107991004021392285783925812861821192530917403151452391805634,
)
BN254_G2_Y = (
    8495653923123431417604973247489272438418190587263600148770280649306958101930,
    4082367875863433681332203403145435568316851327593401208105741076214120093531,
)

# --- BLS12-381: y^2 = x^3 + 4 over F_p ------------------------------------
BLS12_381_P = int(
    "1a0111ea397fe69a4b1ba7b6434bacd764774b84f38512bf6730d2a0f6b0f624"
    "1eabfffeb153ffffb9feffffffffaaab",
    16,
)
BLS12_381_R = int("73eda753299d7d483339d80809a1d80553bda402fffe5bfeffffffff00000001", 16)
BLS12_381_B = 4
BLS12_381_G1 = (
    int(
        "17f1d3a73197d7942695638c4fa9ac0fc3688c4f9774b905a14e3a3f171bac58"
        "6c55e83ff97a1aeffb3af00adb22c6bb",
        16,
    ),
    int(
        "08b3f481e3aaa0f1a09e30ed741d8ae4fcf5e095d5d00af600db18cb2c04b3ed"
        "d03cc744a2888ae40caa232946c5e7e1",
        16,
    ),
)
BLS12_381_FR_GEN = 7
BLS12_381_FR_TWO_ADICITY = 32
BLS12_381_FP_GEN = 2

# BLS12-381 G2 over F_p2 (u^2 = -1), curve y^2 = x^3 + 4(1+u); standard generator.
BLS12_381_G2_X = (
    int(
        "024aa2b2f08f0a91260805272dc51051c6e47ad4fa403b02b4510b647ae3d177"
        "0bac0326a805bbefd48056c8c121bdb8",
        16,
    ),
    int(
        "13e02b6052719f607dacd3a088274f65596bd0d09920b61ab5da61bbdc7f5049"
        "334cf11213945d57e5ac7d055d042b7e",
        16,
    ),
)
BLS12_381_G2_Y = (
    int(
        "0ce5d527727d6e118cc9cdc6da2e351aadfd9baa8cbdd3a76d429a695160d12c"
        "923ac9cc3baca289e193548608b82801",
        16,
    ),
    int(
        "0606c4a02ea734cc32acd2b02bc28b99cb3e287e85a763af267492ab572e99ab"
        "3f370d275cec1da1aaa9075ff05f79be",
        16,
    ),
)

# 16-bit limb counts used by the L1 kernels (batch point engine).
LIMB_BITS = 16
BN254_NLIMB16 = 16   # 256 bits
BLS12_381_NLIMB16 = 24  # 384 bits


class Curve:
    """Bundle of parameters for one curve family."""

    def __init__(self, name, p, r, b, g1, fr_gen, fr_two_adicity, fp_gen,
                 g2_x, g2_y, nlimb16, scalar_bits):
        self.name = name
        self.p = p
        self.r = r
        self.b = b
        self.g1 = g1
        self.fr_gen = fr_gen
        self.fr_two_adicity = fr_two_adicity
        self.fp_gen = fp_gen
        self.g2_x = g2_x
        self.g2_y = g2_y
        self.nlimb16 = nlimb16
        self.scalar_bits = scalar_bits
        # Montgomery parameters for the 16-bit-limb kernel domain:
        # R16 = 2**(16*nlimb16) (equals the rust 64-bit-limb R, by design).
        self.r16 = 1 << (LIMB_BITS * nlimb16)
        self.inv16 = (-pow(p, -1, 1 << LIMB_BITS)) % (1 << LIMB_BITS)
        self.r2 = (self.r16 * self.r16) % p

    def to_mont(self, x):
        return (x * self.r16) % self.p

    def from_mont(self, x):
        return (x * pow(self.r16, -1, self.p)) % self.p

    def limbs16(self, x):
        """Little-endian 16-bit limbs of x (length nlimb16)."""
        return [(x >> (LIMB_BITS * i)) & 0xFFFF for i in range(self.nlimb16)]

    def from_limbs16(self, limbs):
        return sum(int(v) << (LIMB_BITS * i) for i, v in enumerate(limbs))


BN254 = Curve("bn254", BN254_P, BN254_R, BN254_B, BN254_G1, BN254_FR_GEN,
              BN254_FR_TWO_ADICITY, BN254_FP_GEN, BN254_G2_X, BN254_G2_Y,
              BN254_NLIMB16, 254)
BLS12_381 = Curve("bls12_381", BLS12_381_P, BLS12_381_R, BLS12_381_B,
                  BLS12_381_G1, BLS12_381_FR_GEN, BLS12_381_FR_TWO_ADICITY,
                  BLS12_381_FP_GEN, BLS12_381_G2_X, BLS12_381_G2_Y,
                  BLS12_381_NLIMB16, 381)

CURVES = {c.name: c for c in (BN254, BLS12_381)}


def _selfcheck():
    for c in CURVES.values():
        # Fermat witnesses (not full primality proofs, but catch any typo).
        for a in (2, 3, 5, 7):
            assert pow(a, c.p - 1, c.p) == 1, f"{c.name}: p fails Fermat base {a}"
            assert pow(a, c.r - 1, c.r) == 1, f"{c.name}: r fails Fermat base {a}"
        # G1 on curve.
        x, y = c.g1
        assert (y * y - x * x * x - c.b) % c.p == 0, f"{c.name}: G1 not on curve"
        # F_r multiplicative generator has full order (check via factors 2 and
        # the odd part: g^((r-1)/2) != 1).
        assert pow(c.fr_gen, (c.r - 1) // 2, c.r) == c.r - 1
        # 2-adicity: r-1 divisible by 2^s and the 2^s-th root is primitive.
        s = c.fr_two_adicity
        assert (c.r - 1) % (1 << s) == 0 and (c.r - 1) % (1 << (s + 1)) != 0
        root = pow(c.fr_gen, (c.r - 1) >> s, c.r)
        assert pow(root, 1 << (s - 1), c.r) == c.r - 1, f"{c.name}: bad 2^s root"
        # fp_gen is a quadratic nonresidue (needed as Tonelli-Shanks seed).
        assert pow(c.fp_gen, (c.p - 1) // 2, c.p) == c.p - 1
        # p = 3 mod 4 (enables the fast sqrt both curves rely on).
        assert c.p % 4 == 3
        # G2 on curve over F_p2 with u^2 = -1 and b2 = b/(9+u) [BN] or b(1+u) [BLS].
        p = c.p

        def f2_mul(a, b):
            return ((a[0] * b[0] - a[1] * b[1]) % p, (a[0] * b[1] + a[1] * b[0]) % p)

        def f2_inv(a):
            n = pow(a[0] * a[0] + a[1] * a[1], -1, p)
            return (a[0] * n % p, (-a[1]) * n % p)

        if c.name == "bn254":
            b2 = f2_mul((c.b, 0), f2_inv((9, 1)))
        else:
            b2 = ((c.b) % p, (c.b) % p)  # 4*(1+u)
        xx = f2_mul(c.g2_x, c.g2_x)
        x3 = f2_mul(xx, c.g2_x)
        yy = f2_mul(c.g2_y, c.g2_y)
        lhs = ((yy[0] - x3[0] - b2[0]) % p, (yy[1] - x3[1] - b2[1]) % p)
        assert lhs == (0, 0), f"{c.name}: G2 not on curve"
        # Montgomery 16-bit parameters.
        assert (c.p * ((-pow(c.p, -1, 1 << 16)) % (1 << 16)) + 1) % (1 << 16) == 0
        assert c.from_mont(c.to_mont(12345)) == 12345


_selfcheck()
