"""AOT pipeline: lower the L2 graphs to HLO text for the rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts [--batch 256]

Emits per curve:
    uda_<curve>_b<batch>.hlo.txt     the batched UDA point processor
plus a manifest.json the rust `runtime::artifact` module consumes.
"""

import argparse
import hashlib
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from . import model, params


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_uda(curve: params.Curve, batch: int, block: int) -> str:
    fn = model.uda_batch_fn(curve, block=block)
    lowered = jax.jit(fn).lower(*model.example_args(curve, batch))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=256,
                    help="engine batch size (rows per execute call)")
    ap.add_argument("--block", type=int, default=64,
                    help="pallas grid tile rows")
    ap.add_argument("--curves", nargs="*", default=list(params.CURVES),
                    choices=list(params.CURVES))
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"batch": args.batch, "block": args.block, "artifacts": {}}
    for name in args.curves:
        curve = params.CURVES[name]
        text = lower_uda(curve, args.batch, args.block)
        fname = f"uda_{name}_b{args.batch}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"][name] = {
            "file": fname,
            "kind": "uda",
            "curve": name,
            "batch": args.batch,
            "nlimb16": curve.nlimb16,
            "sha256_16": digest,
            "inputs": 6,
            "outputs": 3,
        }
        print(f"wrote {path} ({len(text)} chars, sha {digest})")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
