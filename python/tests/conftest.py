"""Test-session configuration.

XLA's CPU backend takes minutes to optimize the large integer graphs the
UDA kernel lowers to (thousands of u64 ops). Correctness tests don't need
optimized code, so default the backend to -O0 unless the caller already
set XLA_FLAGS. Must run before the first jax import.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_backend_optimization_level=0")
