"""Test-session configuration.

XLA's CPU backend takes minutes to optimize the large integer graphs the
UDA kernel lowers to (thousands of u64 ops). Correctness tests don't need
optimized code, so default the backend to -O0 unless the caller already
set XLA_FLAGS. Must run before the first jax import.

Also makes the suite self-contained:
- puts `python/` on sys.path so `from compile import ...` resolves no
  matter the pytest invocation directory;
- installs the deterministic `_mini_hypothesis` fallback when the real
  hypothesis package is not installed (offline environments).
"""

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_backend_optimization_level=0")

_PY_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _PY_ROOT not in sys.path:
    sys.path.insert(0, _PY_ROOT)

try:
    import hypothesis  # noqa: F401
except ImportError:
    import types

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _mini_hypothesis

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _mini_hypothesis.integers
    _hyp.given = _mini_hypothesis.given
    _hyp.settings = _mini_hypothesis.settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
