"""AOT pipeline: lowering produces loadable HLO text (no XLA *compile* —
lowering is trace-only and fast; execution of the compiled artifact is
covered by the recorded rust-side runs, EXPERIMENTS.md §Perf)."""

import json
import os

from compile import aot, model, params


def test_lower_uda_bn254_produces_hlo_text():
    text = aot.lower_uda(params.BN254, batch=8, block=4)
    assert text.startswith("HloModule")
    # six u32[8,16] inputs and a 3-tuple result in the entry layout
    assert text.count("u32[8,16]") >= 9
    assert "ENTRY" in text


def test_uda_chain_lowers():
    fn = model.uda_chain_fn(params.BN254, steps=2, block=4)
    import jax

    lowered = jax.jit(fn).lower(*model.example_args(params.BN254, 8))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")


def test_manifest_written(tmp_path):
    # run the main() flow against a temp dir with a tiny batch, bn254 only
    import sys

    argv = sys.argv
    sys.argv = [
        "aot",
        "--out-dir",
        str(tmp_path),
        "--batch",
        "8",
        "--block",
        "4",
        "--curves",
        "bn254",
    ]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["batch"] == 8
    entry = manifest["artifacts"]["bn254"]
    assert entry["nlimb16"] == 16
    assert entry["inputs"] == 6 and entry["outputs"] == 3
    assert os.path.exists(tmp_path / entry["file"])
