"""Batched UDA kernel vs the python-int EC oracle.

Two execution modes of the SAME kernel body (`_uda_lanes`):

* **eager** (default) — the lane dataflow evaluated op-by-op, no jit, no
  XLA compile: runs in seconds, used for the full semantic matrix;
* **pallas** (`IFZKP_UDA_PALLAS=1`) — the real `pallas_call(interpret=True)`
  + jit path the AOT artifact uses. XLA takes ~10 minutes to compile the
  UDA graph per curve on this CPU, so it is opt-in; the recorded runs are
  in EXPERIMENTS.md (§E2E also replays the compiled artifact from rust).
"""

import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.params import BN254, CURVES
from compile.kernels import modmul, point_ops, ref

CURVE_LIST = list(CURVES.values())
PALLAS = bool(os.environ.get("IFZKP_UDA_PALLAS"))
BATCH = 4


def pts_to_arrays(points, curve):
    cols = [[], [], []]
    for p in points:
        limbs = ref.point_to_mont_limbs(p, curve)
        for c in range(3):
            cols[c].append(limbs[c])
    return [np.array(c, dtype=np.uint32) for c in cols]


def eq_jac(p, q, curve):
    P = curve.p
    if p[2] == 0 or q[2] == 0:
        return p[2] == 0 and q[2] == 0
    z1z1, z2z2 = p[2] * p[2] % P, q[2] * q[2] % P
    if p[0] * z2z2 % P != q[0] * z1z1 % P:
        return False
    return p[1] * z2z2 * q[2] % P == q[1] * z1z1 * p[2] % P


def run_uda(curve, pairs):
    """Run pairs through the kernel body (eager or pallas per PALLAS)."""
    n = len(pairs)
    assert n <= BATCH
    padded = list(pairs) + [(ref.INF, ref.INF)] * (BATCH - n)
    a = pts_to_arrays([p for p, _ in padded], curve)
    b = pts_to_arrays([q for _, q in padded], curve)
    if PALLAS:
        kernel = point_ops.uda_pallas(curve, block=BATCH)
        out = kernel(a[0], a[1], a[2], b[0], b[1], b[2])
        xs, ys, zs = [np.asarray(o) for o in out]
    else:
        import jax.numpy as jnp

        nl = curve.nlimb16
        uda = point_ops._uda_lanes(curve)
        args = [
            modmul.lanes(jnp.asarray(arr), nl) for arr in (a[0], a[1], a[2], b[0], b[1], b[2])
        ]
        rx, ry, rz = uda(*args)
        xs = np.stack([np.asarray(v) for v in rx], axis=1)
        ys = np.stack([np.asarray(v) for v in ry], axis=1)
        zs = np.stack([np.asarray(v) for v in rz], axis=1)
    out_pts = []
    for i in range(n):
        out_pts.append(
            ref.point_from_mont_limbs(
                (list(xs[i].astype(int)), list(ys[i].astype(int)), list(zs[i].astype(int))),
                curve,
            )
        )
    return out_pts


def some_points(curve, count, seed=7):
    g = ref.generator_jac(curve)
    return [
        ref.jac_scalar_mul(g, (seed * 0x9E3779B9 + i * 1237) % (curve.r - 3) + 2, curve)
        for i in range(count)
    ]


@pytest.mark.parametrize("curve", CURVE_LIST, ids=lambda c: c.name)
def test_uda_generic_adds(curve):
    pts = some_points(curve, 8)
    pairs = list(zip(pts[:4], pts[4:]))
    got = run_uda(curve, pairs)
    for (p, q), r in zip(pairs, got):
        want = ref.jac_add(p, q, curve)
        assert eq_jac(r, want, curve)
        assert ref.is_on_curve_jac(r, curve)


@pytest.mark.parametrize("curve", CURVE_LIST, ids=lambda c: c.name)
def test_uda_pd_check_fires_on_equal_points(curve):
    pts = some_points(curve, 3, seed=11)
    pairs = [(p, p) for p in pts]
    got = run_uda(curve, pairs)
    for p, r in zip(pts, got):
        assert eq_jac(r, ref.jac_double(p, curve), curve)


@pytest.mark.parametrize("curve", CURVE_LIST, ids=lambda c: c.name)
def test_uda_pd_check_fires_across_representations(curve):
    # same point, different Z (the U/S-class comparison, not raw coords)
    g = ref.generator_jac(curve)
    p5 = ref.jac_scalar_mul(g, 5, curve)
    P = curve.p
    x, y, z = p5
    p5b = (x * 9 % P, y * 27 % P, z * 3 % P)
    assert eq_jac(p5, p5b, curve)
    got = run_uda(curve, [(p5, p5b)])
    assert eq_jac(got[0], ref.jac_double(p5, curve), curve)


@pytest.mark.parametrize("curve", CURVE_LIST, ids=lambda c: c.name)
def test_uda_cancellation_and_infinity(curve):
    g = ref.generator_jac(curve)
    p = ref.jac_scalar_mul(g, 777, curve)
    neg = (p[0], (-p[1]) % curve.p, p[2])
    pairs = [
        (p, neg),            # P + (−P) = O
        (ref.INF, p),        # O + P = P
        (p, ref.INF),        # P + O = P
        (ref.INF, ref.INF),  # O + O = O
    ]
    got = run_uda(curve, pairs)
    assert got[0][2] == 0
    assert eq_jac(got[1], p, curve)
    assert eq_jac(got[2], p, curve)
    assert got[3][2] == 0


@settings(max_examples=4, deadline=None)
@given(
    ka=st.integers(min_value=1, max_value=1 << 200),
    kb=st.integers(min_value=1, max_value=1 << 200),
    ci=st.integers(0, 1),
)
def test_uda_hypothesis_random_multiples(ka, kb, ci):
    curve = CURVE_LIST[ci]
    g = ref.generator_jac(curve)
    p = ref.jac_scalar_mul(g, ka % curve.r or 1, curve)
    q = ref.jac_scalar_mul(g, kb % curve.r or 1, curve)
    got = run_uda(curve, [(p, q)])
    assert eq_jac(got[0], ref.jac_add(p, q, curve), curve)


@pytest.mark.skipif(not PALLAS, reason="set IFZKP_UDA_PALLAS=1 (XLA compiles ~10min/curve)")
def test_uda_pallas_grid_tiling_matches_single_block():
    # same batch through 1 tile (block=4) vs 2 tiles (block=2)
    pts = some_points(BN254, 8, seed=13)
    pairs = list(zip(pts[:4], pts[4:]))
    a = pts_to_arrays([p for p, _ in pairs], BN254)
    b = pts_to_arrays([q for _, q in pairs], BN254)
    k4 = point_ops.uda_pallas(BN254, block=4)
    k2 = point_ops.uda_pallas(BN254, block=2)
    o4 = [np.asarray(o) for o in k4(a[0], a[1], a[2], b[0], b[1], b[2])]
    o2 = [np.asarray(o) for o in k2(a[0], a[1], a[2], b[0], b[1], b[2])]
    for x, y in zip(o4, o2):
        assert np.array_equal(x, y)
