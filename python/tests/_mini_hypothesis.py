"""Deterministic, dependency-free fallback for the tiny slice of the
`hypothesis` API these tests use (`given`, `settings`,
`strategies.integers`).

The real hypothesis is preferred when installed (CI installs it); this
fallback keeps the oracle sweeps runnable in offline environments. Cases
are drawn from a fixed-seed RNG, so runs are reproducible. Unbounded
integer strategies sample across magnitudes (8..384 bits) to hit both
small edge cases and full-width operands.
"""

import functools
import random


class _IntStrategy:
    def __init__(self, min_value=None, max_value=None):
        self.min_value = min_value
        self.max_value = max_value

    def example(self, rng):
        lo = self.min_value if self.min_value is not None else -(1 << 64)
        if self.max_value is not None:
            return rng.randint(lo, self.max_value)
        # unbounded above: mixed magnitudes, biased toward small values
        bits = rng.choice([1, 2, 8, 16, 64, 128, 192, 256, 320, 384])
        return lo + rng.getrandbits(bits)


def integers(min_value=None, max_value=None):
    return _IntStrategy(min_value, max_value)


def settings(max_examples=20, deadline=None, **_ignored):
    def deco(fn):
        fn._mini_hyp_max_examples = max_examples
        return fn

    return deco


def given(**strategies_kw):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_mini_hyp_max_examples", 20)
            rng = random.Random(0xC0FFEE ^ hash(fn.__name__))
            for case in range(n):
                drawn = {k: s.example(rng) for k, s in strategies_kw.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except AssertionError as e:
                    raise AssertionError(
                        f"{fn.__name__} failed at case {case} with {drawn}: {e}"
                    ) from e

        # pytest resolves fixtures from the *visible* signature; without
        # this, functools.wraps' __wrapped__ exposes the strategy params
        # (a, b, ...) and pytest treats them as missing fixtures.
        del wrapper.__wrapped__
        return wrapper

    return deco
