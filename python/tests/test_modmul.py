"""L1 modmul kernel vs the python-int oracle (hypothesis-swept)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.params import BLS12_381, BN254, CURVES
from compile.kernels import modmul as mm

CURVE_LIST = list(CURVES.values())


def limbs_arr(curve, values):
    return np.array([curve.limbs16(v) for v in values], dtype=np.uint32)


def from_limbs(curve, arr):
    return [curve.from_limbs16(row) for row in np.asarray(arr)]


@pytest.mark.parametrize("curve", CURVE_LIST, ids=lambda c: c.name)
def test_mont_mul_known_values(curve):
    # (1·R) ∘ (1·R) = 1·R  (Montgomery one is idempotent)
    one_m = curve.to_mont(1)
    a = limbs_arr(curve, [one_m] * 4)
    out = mm.mont_mul(a.astype(np.uint64), a.astype(np.uint64), curve)
    assert from_limbs(curve, out) == [one_m] * 4


@pytest.mark.parametrize("curve", CURVE_LIST, ids=lambda c: c.name)
def test_mont_mul_random_batch(curve):
    rng = np.random.default_rng(1234)
    vals_a = [int(rng.integers(0, 2**63)) * 7919 % curve.p for _ in range(16)]
    vals_b = [curve.p - 1 - v for v in vals_a]
    am = [curve.to_mont(v) for v in vals_a]
    bm = [curve.to_mont(v) for v in vals_b]
    a = limbs_arr(curve, am).astype(np.uint64)
    b = limbs_arr(curve, bm).astype(np.uint64)
    out = from_limbs(curve, mm.mont_mul(a, b, curve))
    for am_i, bm_i, got in zip(am, bm, out):
        want = am_i * bm_i * pow(curve.r16, -1, curve.p) % curve.p
        assert got == want


@settings(max_examples=40, deadline=None)
@given(
    a=st.integers(min_value=0),
    b=st.integers(min_value=0),
    ci=st.integers(min_value=0, max_value=1),
)
def test_mont_mul_hypothesis(a, b, ci):
    curve = CURVE_LIST[ci]
    a %= curve.p
    b %= curve.p
    am, bm = curve.to_mont(a), curve.to_mont(b)
    arr_a = limbs_arr(curve, [am]).astype(np.uint64)
    arr_b = limbs_arr(curve, [bm]).astype(np.uint64)
    got = from_limbs(curve, mm.mont_mul(arr_a, arr_b, curve))[0]
    assert curve.from_mont(got) == a * b % curve.p


def run_lanes(fn, curve, a_vals, b_vals):
    """Apply a lane-level op to canonical ints; return canonical ints."""
    nl = curve.nlimb16
    a = mm.lanes(limbs_arr(curve, a_vals), nl)
    b = mm.lanes(limbs_arr(curve, b_vals), nl)
    out = mm.unlanes(fn(a, b, curve.limbs16(curve.p), nl))
    return from_limbs(curve, out)


@settings(max_examples=30, deadline=None)
@given(a=st.integers(min_value=0), b=st.integers(min_value=0), ci=st.integers(0, 1))
def test_mod_add_sub_hypothesis(a, b, ci):
    curve = CURVE_LIST[ci]
    a %= curve.p
    b %= curve.p
    s = run_lanes(mm.mod_add, curve, [a], [b])[0]
    d = run_lanes(mm.mod_sub, curve, [a], [b])[0]
    assert s == (a + b) % curve.p
    assert d == (a - b) % curve.p


@pytest.mark.parametrize("curve", CURVE_LIST, ids=lambda c: c.name)
def test_edge_values(curve):
    pm1 = curve.p - 1
    cases_a = [0, 1, pm1, curve.to_mont(1)]
    cases_b = [pm1, pm1, pm1, 0]
    a = limbs_arr(curve, cases_a).astype(np.uint64)
    b = limbs_arr(curve, cases_b).astype(np.uint64)
    rinv = pow(curve.r16, -1, curve.p)
    got = from_limbs(curve, mm.mont_mul(a, b, curve))
    for x, y, g in zip(cases_a, cases_b, got):
        assert g == x * y * rinv % curve.p
    s = run_lanes(mm.mod_add, curve, cases_a, cases_b)
    for x, y, g in zip(cases_a, cases_b, s):
        assert g == (x + y) % curve.p


@pytest.mark.parametrize("curve", CURVE_LIST, ids=lambda c: c.name)
@pytest.mark.parametrize("block", [32, 64])
def test_pallas_modmul_matches_jnp(curve, block):
    rng = np.random.default_rng(99)
    batch = 128
    vals_a = [int.from_bytes(rng.bytes(32), "little") % curve.p for _ in range(batch)]
    vals_b = [int.from_bytes(rng.bytes(32), "little") % curve.p for _ in range(batch)]
    a = limbs_arr(curve, [curve.to_mont(v) for v in vals_a])
    b = limbs_arr(curve, [curve.to_mont(v) for v in vals_b])
    kernel = mm.modmul_pallas(curve, block=block)
    out = from_limbs(curve, np.asarray(kernel(a, b)))
    for va, vb, got in zip(vals_a, vals_b, out):
        assert curve.from_mont(got) == va * vb % curve.p


def test_pallas_rejects_ragged_batch():
    kernel = mm.modmul_pallas(BN254, block=64)
    a = np.zeros((65, BN254.nlimb16), dtype=np.uint32)
    with pytest.raises(AssertionError):
        kernel(a, a)


def test_r16_radix_matches_u64_radix():
    # The repack-without-arithmetic property the rust runtime relies on.
    assert BN254.r16 == 1 << 256
    assert BLS12_381.r16 == 1 << 384
